//! Algorithm 3 — Disaggregated Mode Performance Estimation.
//!
//! Prefill and decode candidates are priced independently as static
//! instances (Algorithm 1), prefill latency corrected by β_TTFT for the
//! KV-cache transfer, then composed into (x)P(y)D servers by
//! **rate matching**: system request rate R_sys = min(R_pre, R_dec) with
//! per-pool degradation factors α, maximizing per-GPU throughput.

use crate::config::{EngineConfig, WorkloadSpec};
use crate::hardware::ClusterSpec;
use crate::models::ModelArch;
use crate::perfdb::LatencyOracle;

use super::iteration::IterCtx;
use super::{static_mode, PerfEstimate};

/// Degradation factor α_pre (prefill pool interference).
pub const ALPHA_PRE: f64 = 0.9;
/// Degradation factor α_dec (decode pool interference).
pub const ALPHA_DEC: f64 = 0.92;
/// TTFT correction β_TTFT for KV-cache transmission overhead.
pub const BETA_TTFT: f64 = 1.8;

/// Per-pool pricing of one engine as an isolated static instance.
#[derive(Clone, Copy, Debug)]
pub struct PoolPrice {
    /// Prefill completion latency for one batch, ms (pool = prefill),
    /// or per-token decode step latency, ms (pool = decode).
    pub latency_ms: f64,
    /// Sustained request rate of ONE worker, requests/s.
    pub req_rate: f64,
    pub gpus: u32,
    /// KV-cache bytes one request ships to the decode pool (prefill
    /// pools only; 0 for decode pools). Priced over the fabric path by
    /// [`compose_on`] on tiered fabrics — legacy fabrics keep the
    /// seed's β_TTFT-only correction bit-for-bit.
    pub kv_bytes: f64,
}

/// Price a prefill engine: batch `b_pre` prompts prefilled per step.
pub fn price_prefill(
    oracle: &dyn LatencyOracle,
    model: &ModelArch,
    cluster: &ClusterSpec,
    eng: &EngineConfig,
    wl: &WorkloadSpec,
) -> PoolPrice {
    let ctx = IterCtx::new(oracle, model, cluster, eng);
    let isl = wl.isl.max(1) as u64;
    let isl_eff = isl.saturating_sub(wl.prefix as u64).max(1);
    let lat = ctx.prefill_step_ms(eng.batch, isl_eff, isl);
    PoolPrice {
        latency_ms: lat,
        req_rate: eng.batch as f64 / (lat / 1000.0),
        gpus: eng.parallel.gpus(),
        kv_bytes: model.kv_bytes_per_token(eng.kv_dtype) * wl.isl.max(1) as f64,
    }
}

/// Price a decode engine: steady-state decode at batch `b_dec`.
pub fn price_decode(
    oracle: &dyn LatencyOracle,
    model: &ModelArch,
    cluster: &ClusterSpec,
    eng: &EngineConfig,
    wl: &WorkloadSpec,
) -> PoolPrice {
    let ctx = IterCtx::new(oracle, model, cluster, eng);
    // Average decode-step latency over the generation (Algorithm 1 TPOT
    // with zero-cost prefill — the pool never prefills).
    let (_, tpot) = static_mode::estimate(&ctx, wl.isl as u64, wl.osl.max(2) as u64, wl.isl as u64, eng.batch);
    let osl = wl.osl.max(1) as f64;
    PoolPrice {
        latency_ms: tpot,
        // Each worker completes B requests every OSL·TPOT ms.
        req_rate: eng.batch as f64 / (osl * tpot / 1000.0),
        gpus: eng.parallel.gpus(),
        kv_bytes: 0.0,
    }
}

/// Estimate one concrete (x)P(y)D composite (used by [`super::estimate`]).
#[allow(clippy::too_many_arguments)]
pub fn estimate_composite(
    oracle: &dyn LatencyOracle,
    model: &ModelArch,
    cluster: &ClusterSpec,
    prefill: &EngineConfig,
    decode: &EngineConfig,
    x: u32,
    y: u32,
    wl: &WorkloadSpec,
) -> PerfEstimate {
    let p = price_prefill(oracle, model, cluster, prefill, wl);
    let d = price_decode(oracle, model, cluster, decode, wl);
    compose_on(cluster, &p, &d, x, y, wl)
}

/// [`compose`] with the KV-transfer path priced over the cluster's
/// fabric. The seed's β_TTFT surcharge stands in for queueing *and*
/// the KV transfer; on a tiered fabric the transfer is priced
/// physically — NVLink when the whole (x)P(y)D composite fits one
/// NVLink domain, an IB rail when it spans domains — and the TTFT
/// charges whichever of {β surcharge, physical transfer} is larger
/// instead of stacking both (no double count). Legacy fabrics price
/// exactly as [`compose`] (pinned).
pub fn compose_on(
    cluster: &ClusterSpec,
    p: &PoolPrice,
    d: &PoolPrice,
    x: u32,
    y: u32,
    wl: &WorkloadSpec,
) -> PerfEstimate {
    let mut est = compose(p, d, x, y, wl);
    if cluster.fabric.placement_aware() && p.kv_bytes > 0.0 {
        let spans = x * p.gpus + y * d.gpus > cluster.domain_size();
        let transfer_ms =
            crate::topology::collective::p2p_us(cluster, p.kv_bytes, spans, 1) / 1000.0;
        let surcharge_ms = (BETA_TTFT - 1.0) * p.latency_ms;
        est.ttft_ms = p.latency_ms + surcharge_ms.max(transfer_ms);
    }
    est
}

/// Rate-match a priced pool pair into a PerfEstimate (the seed's
/// fabric-blind composition: β_TTFT absorbs the KV transfer).
pub fn compose(p: &PoolPrice, d: &PoolPrice, x: u32, y: u32, wl: &WorkloadSpec) -> PerfEstimate {
    let g_total = x * p.gpus + y * d.gpus;
    let r_pre = p.req_rate * x as f64 * ALPHA_PRE;
    let r_dec = d.req_rate * y as f64 * ALPHA_DEC;
    let r_sys = r_pre.min(r_dec); // requests/s
    let ttft = p.latency_ms * BETA_TTFT;
    let tpot = d.latency_ms;
    let osl = wl.osl.max(1) as f64;
    let thru_per_gpu = r_sys * osl / g_total as f64;
    PerfEstimate {
        ttft_ms: ttft,
        tpot_ms: tpot,
        speed: if tpot > 0.0 { 1000.0 / tpot } else { f64::INFINITY },
        thru_per_gpu,
        // Steady-state concurrency: Little's law on the decode pool
        // (R_sys requests/s × per-request residency OSL·TPOT seconds).
        concurrency: ((r_sys * osl * tpot / 1000.0) as u32).max(y.max(1)),
    }
}

/// Algorithm 3 proper: filter candidate pools by SLA, sweep worker
/// counts, return every valid composite (the Pareto analyzer consumes
/// all of them) plus the argmax-throughput one.
pub struct RateMatchResult {
    /// (x, y, prefill idx, decode idx, estimate) per evaluated composite.
    pub evaluated: Vec<(u32, u32, usize, usize, PerfEstimate)>,
    /// Index into `evaluated` of the best per-GPU throughput.
    pub best: Option<usize>,
}

/// Algorithm 3 with **incremental Pareto pruning**: identical filtering
/// and sweep order to [`rate_match`] (one shared loop body), but each
/// composite is offered to a running (speed, throughput) frontier and
/// discarded immediately when dominated — the evaluated set stays
/// frontier-sized instead of O(max_x · max_y · pairs). The accumulator
/// may be shared with the aggregated sweep so pruning is global across
/// serving modes; `best` is then the argmax over the *kept* composites
/// (a composite dominated by an externally offered point is discarded
/// by design).
pub fn rate_match_pruned(
    cluster: &ClusterSpec,
    prefill_prices: &[PoolPrice],
    decode_prices: &[PoolPrice],
    wl: &WorkloadSpec,
    max_gpus: u32,
    g_valid: &[u32],
    max_x: u32,
    max_y: u32,
    acc: &mut crate::pareto::FrontierAccumulator,
) -> RateMatchResult {
    rate_match_core(
        cluster,
        prefill_prices,
        decode_prices,
        wl,
        max_gpus,
        g_valid,
        max_x,
        max_y,
        Some(acc),
    )
}

/// `g_valid` restricts total GPU counts (e.g. multiples available on the
/// cluster); empty slice = any count up to the cluster size.
pub fn rate_match(
    cluster: &ClusterSpec,
    prefill_prices: &[PoolPrice],
    decode_prices: &[PoolPrice],
    wl: &WorkloadSpec,
    max_gpus: u32,
    g_valid: &[u32],
    max_x: u32,
    max_y: u32,
) -> RateMatchResult {
    rate_match_core(cluster, prefill_prices, decode_prices, wl, max_gpus, g_valid, max_x, max_y, None)
}

/// One loop body for both variants, so the filters and sweep order can
/// never desynchronize. Ties on throughput keep the first-seen
/// composite in either mode.
#[allow(clippy::too_many_arguments)]
fn rate_match_core(
    cluster: &ClusterSpec,
    prefill_prices: &[PoolPrice],
    decode_prices: &[PoolPrice],
    wl: &WorkloadSpec,
    max_gpus: u32,
    g_valid: &[u32],
    max_x: u32,
    max_y: u32,
    mut acc: Option<&mut crate::pareto::FrontierAccumulator>,
) -> RateMatchResult {
    let mut evaluated = Vec::new();
    let mut best: Option<usize> = None;
    // Step 1: filter by latency constraints.
    let ttft_lim = wl.sla.ttft_ms;
    let tpot_lim = wl.sla.max_tpot_ms();
    for (di, d) in decode_prices.iter().enumerate() {
        if d.latency_ms > tpot_lim {
            continue;
        }
        for (pi, p) in prefill_prices.iter().enumerate() {
            if p.latency_ms * BETA_TTFT > ttft_lim {
                continue;
            }
            // Step 2: sweep worker counts.
            for x in 1..=max_x {
                for y in 1..=max_y {
                    let g_total = x * p.gpus + y * d.gpus;
                    if g_total > max_gpus {
                        continue;
                    }
                    if !g_valid.is_empty() && !g_valid.contains(&g_total) {
                        continue;
                    }
                    let est = compose_on(cluster, p, d, x, y, wl);
                    if let Some(acc) = acc.as_deref_mut() {
                        if !acc.offer_est(&est) {
                            continue;
                        }
                    }
                    evaluated.push((x, y, pi, di, est));
                    let i = evaluated.len() - 1;
                    let improves = match best {
                        Some(b) => est.thru_per_gpu > evaluated[b].4.thru_per_gpu,
                        None => true,
                    };
                    if improves {
                        best = Some(i);
                    }
                }
            }
        }
    }
    RateMatchResult { evaluated, best }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Sla;

    fn wl() -> WorkloadSpec {
        WorkloadSpec {
            model: "qwen3-32b".into(),
            isl: 4000,
            osl: 500,
            prefix: 0,
            sla: Sla { ttft_ms: 1200.0, min_speed: 20.0 },
        }
    }

    fn pp(lat: f64, rate: f64, gpus: u32) -> PoolPrice {
        PoolPrice { latency_ms: lat, req_rate: rate, gpus, kv_bytes: 0.0 }
    }

    fn legacy_cluster() -> ClusterSpec {
        ClusterSpec::new(crate::hardware::h100_sxm(), 8, 4)
    }

    #[test]
    fn rate_matching_takes_min() {
        let w = wl();
        let p = pp(500.0, 2.0, 1); // 2 req/s per prefill worker
        let d = pp(20.0, 1.0, 2); // 1 req/s per decode worker
        let e = compose(&p, &d, 1, 1, &w);
        // R_sys = min(2*0.9, 1*0.92) = 0.92 req/s over 3 GPUs × 500 tokens.
        assert!((e.thru_per_gpu - 0.92 * 500.0 / 3.0).abs() < 1e-6);
        assert!((e.ttft_ms - 900.0).abs() < 1e-9); // β=1.8
        assert!((e.speed - 50.0).abs() < 1e-9);
    }

    #[test]
    fn tiered_fabric_prices_spanning_kv_transfer() {
        let w = wl();
        // A fast prefill pool (20 ms) shipping ~2 GB of KV: the β
        // surcharge (0.8 × 20 = 16 ms) is below the physical transfer,
        // so the fabric path decides the TTFT.
        let mut p = pp(20.0, 3.0, 2);
        p.kv_bytes = 2e9;
        let d = pp(25.0, 1.0, 2);
        let tiered = ClusterSpec::with_fabric(
            crate::hardware::h100_sxm(),
            8,
            4,
            crate::topology::fabric::hgx_h100(),
        );
        // Legacy composition is pinned: β_TTFT only, no fabric term.
        assert_eq!(
            compose_on(&legacy_cluster(), &p, &d, 4, 4, &w).ttft_ms,
            compose(&p, &d, 4, 4, &w).ttft_ms
        );
        // In-domain composite pays the NVLink hop; a domain-spanning
        // one pays the IB rail — materially dearer. Neither stacks the
        // β surcharge on top of the physical transfer (no double
        // count): TTFT never exceeds latency + max(surcharge, transfer).
        let near = compose_on(&tiered, &p, &d, 1, 1, &w);
        let far = compose_on(&tiered, &p, &d, 4, 4, &w);
        assert!(
            far.ttft_ms > near.ttft_ms + 20.0,
            "near={} far={}",
            near.ttft_ms,
            far.ttft_ms
        );
        let transfer_ib_ms =
            (tiered.fabric.ib_latency_us + 2e9 / (tiered.fabric.rail_gbs * 1e3 * 0.9)) / 1000.0;
        assert!(
            far.ttft_ms <= p.latency_ms + transfer_ib_ms + 1.0,
            "β surcharge stacked on the physical transfer: {}",
            far.ttft_ms
        );
        // A slow prefill pool keeps the β floor: the surcharge already
        // covers a cheap in-domain hop.
        let mut slow = pp(300.0, 3.0, 2);
        slow.kv_bytes = 2e9;
        let floor = compose_on(&tiered, &slow, &d, 1, 1, &w);
        assert!(
            (floor.ttft_ms - compose(&slow, &d, 1, 1, &w).ttft_ms).abs() < 1e-9,
            "β floor lost: {}",
            floor.ttft_ms
        );
    }

    #[test]
    fn filter_rejects_slow_pools() {
        let w = wl(); // TTFT ≤ 1200 → prefill lat ≤ 666.7; TPOT ≤ 50
        let res = rate_match(
            &legacy_cluster(),
            &[pp(700.0, 2.0, 1), pp(300.0, 3.0, 1)],
            &[pp(60.0, 1.0, 2), pp(30.0, 1.0, 2)],
            &w,
            16,
            &[],
            4,
            4,
        );
        // Only (prefill#1, decode#1) pairs survive.
        assert!(res.evaluated.iter().all(|(_, _, pi, di, _)| *pi == 1 && *di == 1));
        assert!(res.best.is_some());
    }

    #[test]
    fn g_valid_restricts_totals() {
        let w = wl();
        let res =
            rate_match(&legacy_cluster(), &[pp(100.0, 5.0, 2)], &[pp(25.0, 1.0, 2)], &w, 64, &[8], 8, 8);
        assert!(!res.evaluated.is_empty());
        for (x, y, _, _, _) in &res.evaluated {
            assert_eq!(x * 2 + y * 2, 8);
        }
    }

    #[test]
    fn pruned_rate_match_keeps_best_and_frontier() {
        let w = wl();
        let p = [pp(100.0, 5.0, 1), pp(300.0, 8.0, 2)];
        let d = [pp(25.0, 1.0, 1), pp(40.0, 1.5, 2)];
        let full = rate_match(&legacy_cluster(), &p, &d, &w, 32, &[], 8, 16);
        let mut acc = crate::pareto::FrontierAccumulator::new();
        let pruned = rate_match_pruned(&legacy_cluster(), &p, &d, &w, 32, &[], 8, 16, &mut acc);
        assert!(!pruned.evaluated.is_empty());
        assert!(
            pruned.evaluated.len() < full.evaluated.len(),
            "pruning should discard dominated composites ({} vs {})",
            pruned.evaluated.len(),
            full.evaluated.len()
        );
        // The argmax-throughput composite survives pruning exactly.
        let best_full = full.evaluated[full.best.unwrap()].4.thru_per_gpu;
        let best_pruned = pruned.evaluated[pruned.best.unwrap()].4.thru_per_gpu;
        assert_eq!(best_full, best_pruned);
        // Every frontier value of the full sweep is present in the pruned set.
        let ests: Vec<_> = full.evaluated.iter().map(|e| e.4).collect();
        for &i in &crate::pareto::frontier_indices(&ests) {
            let e = &full.evaluated[i].4;
            assert!(
                pruned.evaluated.iter().any(|(_, _, _, _, q)| {
                    q.speed == e.speed && q.thru_per_gpu == e.thru_per_gpu
                }),
                "frontier point lost in pruning"
            );
        }
    }

    #[test]
    fn best_maximizes_per_gpu_throughput() {
        let w = wl();
        let res = rate_match(
            &legacy_cluster(),
            &[pp(100.0, 5.0, 1)],
            &[pp(25.0, 1.0, 1)],
            &w,
            32,
            &[],
            8,
            8,
        );
        let best = &res.evaluated[res.best.unwrap()];
        for e in &res.evaluated {
            assert!(e.4.thru_per_gpu <= best.4.thru_per_gpu + 1e-12);
        }
        // Rate-matched optimum: R_pre x=1 gives 4.5 req/s; decode workers
        // 0.92 each → balance near y≈5 per x=1.
        let (x, y, ..) = *best;
        assert!(y >= 4 * x && y <= 6 * x, "x={x} y={y}");
    }
}

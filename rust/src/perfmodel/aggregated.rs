//! Algorithm 2 — Aggregated Mode (Continuous Batching) Performance
//! Estimation.
//!
//! Two-phase approximation of inflight batching: a **mixed phase** where
//! prefill chunks and decode streams share iterations (with the
//! rate-matching throttle when context work dominates), and a
//! **generation-only phase** once the prefill backlog drains. TTFT uses
//! the empirical piecewise-linear correction factor F_corr; TPOT is the
//! phase-weighted average with the 3-step jitter offset. (Paper
//! Algorithm 2, verbatim structure.)

use crate::config::{EngineConfig, WorkloadSpec};
use crate::hardware::ClusterSpec;
use crate::models::ModelArch;
use crate::perfdb::LatencyOracle;

use super::iteration::IterCtx;

/// Returns (TTFT ms, TPOT ms) for one aggregated engine instance at
/// batch size B = `eng.batch` under the workload.
pub fn estimate(
    oracle: &dyn LatencyOracle,
    model: &ModelArch,
    cluster: &ClusterSpec,
    eng: &EngineConfig,
    wl: &WorkloadSpec,
) -> (f64, f64) {
    let ctx = IterCtx::new(oracle, model, cluster, eng);
    estimate_ctx(&ctx, wl.isl as u64, wl.osl as u64, eng.batch)
}

/// Core of Algorithm 2 (separated for direct testing).
pub fn estimate_ctx(ctx: &IterCtx, isl: u64, osl: u64, batch: u32) -> (f64, f64) {
    let b = batch.max(1) as u64;
    let isl = isl.max(1);
    let osl = osl.max(1);
    // Context capacity C_ctx: the engine's max-num-tokens flag, but never
    // below one full prompt chunk when chunking is off.
    let c_ctx = if ctx.eng.flags.chunked_prefill {
        ctx.eng.flags.max_num_tokens as u64
    } else {
        (ctx.eng.flags.max_num_tokens as u64).max(isl)
    }
    .max(1);

    // Step 1: phase duration (in steps).
    let t_total_ctx = (isl * b).div_ceil(c_ctx); // steps to prefill everything

    // Step 2: workload distribution.
    let (t_mix, t_gen, n_mix_ctx, n_mix_gen);
    if b > 1 {
        if t_total_ctx >= osl {
            // Context dominates; throttle decode streams (rate matching).
            t_mix = t_total_ctx;
            t_gen = 0u64;
            n_mix_ctx = c_ctx;
            n_mix_gen = (b as f64 / (t_total_ctx as f64 / osl as f64)).floor().max(1.0) as u64;
        } else {
            // Standard continuous batching.
            t_mix = t_total_ctx;
            t_gen = osl - t_mix;
            n_mix_ctx = c_ctx;
            n_mix_gen = b.saturating_sub(c_ctx.div_ceil(isl)).max(1);
        }
    } else {
        t_mix = 1;
        t_gen = osl - 1;
        n_mix_ctx = c_ctx;
        n_mix_gen = 0;
    }

    // Step 3: latency of the two step kinds.
    let l_mix = ctx.mix_step_ms(n_mix_ctx.min(isl * b), n_mix_gen, isl, osl);
    let l_gen = ctx.decode_step_ms(b, isl + osl / 2);

    // Step 4: TTFT with the empirical correction factor.
    let f_corr = (2.0 + (t_total_ctx as f64 - 3.0) / 20.0).min(4.0).max(1.0);
    let ttft = l_mix * isl.div_ceil(c_ctx) as f64 * f_corr;

    // Step 5: TPOT (3-step jitter offset on the mixed-phase weight).
    let tpot = if b > 1 {
        let t_mix_p = t_mix.saturating_sub(3).max(1) as f64;
        let t_gen_f = t_gen as f64;
        (l_mix * t_mix_p + l_gen * t_gen_f) / (t_mix_p + t_gen_f)
    } else {
        l_gen
    };

    (ttft, tpot)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ParallelSpec, RuntimeFlags};
    use crate::frameworks::Framework;
    use crate::hardware::h100_sxm;
    use crate::models::{by_name, Dtype};
    use crate::silicon::Silicon;

    fn fixture(batch: u32) -> (Silicon, crate::models::ModelArch, ClusterSpec, EngineConfig) {
        let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
        (
            Silicon::new(cluster, Framework::TrtLlm.profile()),
            by_name("qwen3-32b").unwrap(),
            cluster,
            EngineConfig {
                framework: Framework::TrtLlm,
                parallel: ParallelSpec::tp(2),
                batch,
                weight_dtype: Dtype::Fp8,
                kv_dtype: Dtype::Fp8,
                flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
                placement: crate::topology::Placement::packed(),
            },
        )
    }

    #[test]
    fn batch_one_tpot_is_pure_decode() {
        let (sil, m, c, e) = fixture(1);
        let ctx = IterCtx::new(&sil, &m, &c, &e);
        let (_, tpot) = estimate_ctx(&ctx, 2048, 256, 1);
        let gen = ctx.decode_step_ms(1, 2048 + 128);
        assert!((tpot - gen).abs() < 1e-9);
    }

    #[test]
    fn tpot_above_pure_decode_for_big_batch() {
        // Prefill interference makes aggregated TPOT worse than a pure
        // decode step — the effect disaggregation removes.
        let (sil, m, c, e) = fixture(64);
        let ctx = IterCtx::new(&sil, &m, &c, &e);
        let (_, tpot) = estimate_ctx(&ctx, 4096, 512, 64);
        let pure = ctx.decode_step_ms(64, 4096 + 256);
        assert!(tpot > pure * 1.1, "tpot={tpot} pure={pure}");
    }

    #[test]
    fn ttft_grows_with_chunk_count() {
        // Algorithm 2's TTFT scales with ceil(ISL / C_ctx): prompts longer
        // than the context capacity need proportionally more mixed steps.
        let (sil, m, c, e) = fixture(16);
        let ctx = IterCtx::new(&sil, &m, &c, &e);
        let (t1, _) = estimate_ctx(&ctx, 8192, 256, 16); // 1 chunk of 8192
        let (t2, _) = estimate_ctx(&ctx, 32768, 256, 16); // 4 chunks
        assert!(t2 > t1 * 2.5, "t1={t1} t2={t2}");
    }

    #[test]
    fn f_corr_bounds() {
        // The correction factor saturates: huge context backlogs don't
        // produce unbounded TTFT multipliers.
        let (sil, m, c, e) = fixture(128);
        let ctx = IterCtx::new(&sil, &m, &c, &e);
        let (t_small, _) = estimate_ctx(&ctx, 4096, 128, 8);
        let (t_big, _) = estimate_ctx(&ctx, 4096, 128, 128);
        // Same per-chunk latency; F_corr ratio bounded by 4/2.
        assert!(t_big / t_small < 3.0, "ratio {}", t_big / t_small);
    }

    #[test]
    fn context_dominated_regime_throttles_decode() {
        let (sil, m, c, e) = fixture(128);
        let ctx = IterCtx::new(&sil, &m, &c, &e);
        // ISL≫OSL: T_total_ctx >= OSL triggers the rate-matching branch;
        // the estimate must stay finite and ordered.
        let (ttft, tpot) = estimate_ctx(&ctx, 16384, 32, 128);
        assert!(ttft.is_finite() && tpot.is_finite());
        assert!(ttft > tpot);
    }
}

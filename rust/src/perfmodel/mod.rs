//! Serving-mode performance models (paper §4.2): derive TTFT / TPOT /
//! generation speed / per-GPU system throughput for a candidate
//! configuration, from operator latencies answered by a
//! [`LatencyOracle`] (the PerfDatabase on the search path, or raw
//! silicon for oracle-gap experiments).
//!
//! * [`static_mode`] — Algorithm 1 (stride-interpolated decode sweep).
//! * [`aggregated`] — Algorithm 2 (continuous batching with the mixed /
//!   generation-only phase split and the F_corr TTFT correction).
//! * [`disagg`] — Algorithm 3 (per-pool filtering + (x)P(y)D rate
//!   matching with α/β degradation factors).

pub mod aggregated;
pub mod disagg;
pub mod iteration;
pub mod memory;
pub mod moe;
pub mod static_mode;

use crate::config::{Candidate, WorkloadSpec};
use crate::hardware::ClusterSpec;
use crate::models::ModelArch;
use crate::perfdb::LatencyOracle;

/// Performance projection for one candidate (the paper's Eq. 1–2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PerfEstimate {
    pub ttft_ms: f64,
    pub tpot_ms: f64,
    /// Generation speed, tokens/s per user = 1000 / TPOT (Eq. 1).
    pub speed: f64,
    /// System throughput, tokens/s per GPU (Eq. 2).
    pub thru_per_gpu: f64,
    /// Steady-state concurrent requests assumed.
    pub concurrency: u32,
}

impl PerfEstimate {
    pub fn from_latencies(
        ttft_ms: f64,
        tpot_ms: f64,
        batch: u32,
        osl: u32,
        total_gpus: u32,
    ) -> PerfEstimate {
        let speed = if tpot_ms > 0.0 { 1000.0 / tpot_ms } else { f64::INFINITY };
        // Eq. 2: requests complete every TTFT + (OSL-1)·TPOT ms; `batch`
        // run concurrently; each yields OSL tokens.
        let per_req_ms = ttft_ms + (osl.saturating_sub(1)) as f64 * tpot_ms;
        let thru = 1000.0 / per_req_ms * batch as f64 * osl as f64 / total_gpus as f64;
        PerfEstimate { ttft_ms, tpot_ms, speed, thru_per_gpu: thru, concurrency: batch }
    }

    /// Does this estimate satisfy the SLA?
    pub fn meets(&self, sla: &crate::config::Sla) -> bool {
        self.ttft_ms <= sla.ttft_ms && self.speed >= sla.min_speed
    }
}

/// Estimate a full candidate deployment against a workload — the
/// "InferenceSession" step of the paper's workflow (§4.1 step 3).
pub fn estimate(
    oracle: &dyn LatencyOracle,
    model: &ModelArch,
    cluster: &ClusterSpec,
    cand: &Candidate,
    wl: &WorkloadSpec,
) -> PerfEstimate {
    match cand {
        Candidate::Aggregated { engine, replicas } => {
            let (ttft, tpot) = aggregated::estimate(oracle, model, cluster, engine, wl);
            // Replicas scale concurrency and GPUs together; per-GPU
            // throughput is replica-invariant.
            let est = PerfEstimate::from_latencies(
                ttft,
                tpot,
                engine.batch * replicas,
                wl.osl,
                engine.parallel.gpus() * replicas,
            );
            est
        }
        Candidate::Disaggregated { prefill, decode, x, y } => {
            disagg::estimate_composite(oracle, model, cluster, prefill, decode, *x, *y, wl)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Sla;

    #[test]
    fn eq2_throughput_units() {
        // TTFT 1000ms, TPOT 50ms, OSL 101, batch 10, 2 GPUs:
        // per-request = 1000 + 100*50 = 6000 ms → 1/6 req/s × 10 × 101
        // tokens / 2 gpus = 84.17 tokens/s/gpu.
        let e = PerfEstimate::from_latencies(1000.0, 50.0, 10, 101, 2);
        assert!((e.thru_per_gpu - 84.1666).abs() < 0.01, "{}", e.thru_per_gpu);
        assert!((e.speed - 20.0).abs() < 1e-9);
    }

    #[test]
    fn sla_check() {
        let e = PerfEstimate::from_latencies(900.0, 40.0, 8, 100, 8);
        assert!(e.meets(&Sla { ttft_ms: 1000.0, min_speed: 20.0 }));
        assert!(!e.meets(&Sla { ttft_ms: 800.0, min_speed: 20.0 }));
        assert!(!e.meets(&Sla { ttft_ms: 1000.0, min_speed: 30.0 }));
    }
}

//! Summary statistics used by metrics, the simulator and benches.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        let xs = [1.0, 2.0, 3.0, 4.0, 100.0];
        assert_eq!(mean(&xs), 22.0);
        assert_eq!(median(&xs), 3.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 99.0), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }
}

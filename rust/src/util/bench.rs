//! Minimal benchmark harness (criterion is not in the vendored crate
//! set — see DESIGN.md). Criterion-like reporting: warmup, N timed
//! samples, median / mean / p95, printed as
//! `name                time: [median 1.234 ms]  mean 1.3 ms  p95 1.5 ms`.

use std::time::Instant;

use super::stats;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples_ms: Vec<f64>,
    /// Work items processed per iteration (candidates, ops, queries…);
    /// 0 when the bench has no natural item count. Set by
    /// [`bench_items`] so [`BenchResult::throughput_per_s`] can report
    /// items/sec off the median sample.
    pub items_per_iter: usize,
}

impl BenchResult {
    pub fn median_ms(&self) -> f64 {
        stats::median(&self.samples_ms)
    }

    pub fn mean_ms(&self) -> f64 {
        stats::mean(&self.samples_ms)
    }

    pub fn p95_ms(&self) -> f64 {
        stats::percentile(&self.samples_ms, 95.0)
    }

    /// Items per second at the median sample (`None` when the bench
    /// declared no item count or the median is zero).
    pub fn throughput_per_s(&self) -> Option<f64> {
        let med = self.median_ms();
        if self.items_per_iter == 0 || med <= 0.0 {
            return None;
        }
        Some(self.items_per_iter as f64 / (med / 1e3))
    }

    pub fn report(&self) -> String {
        let mut line = format!(
            "{:<44} time: [median {:>10}]  mean {:>10}  p95 {:>10}",
            self.name,
            fmt_ms(self.median_ms()),
            fmt_ms(self.mean_ms()),
            fmt_ms(self.p95_ms())
        );
        if let Some(thru) = self.throughput_per_s() {
            line.push_str(&format!("  thrpt: {} items/s", fmt_count(thru)));
        }
        line
    }
}

/// Compact count formatting for throughput lines (`12.3k`, `4.56M`).
fn fmt_count(v: f64) -> String {
    if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1}k", v / 1e3)
    } else {
        format!("{v:.0}")
    }
}

fn fmt_ms(ms: f64) -> String {
    if ms < 0.001 {
        format!("{:.3} µs", ms * 1000.0)
    } else if ms < 1.0 {
        format!("{:.1} µs", ms * 1000.0)
    } else if ms < 1000.0 {
        format!("{ms:.3} ms")
    } else {
        format!("{:.3} s", ms / 1000.0)
    }
}

/// Run `f` with `warmup` unmeasured + `samples` measured iterations and
/// print a criterion-style line. Returns the samples for assertions.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, f: F) -> BenchResult {
    bench_items(name, warmup, samples, 0, f)
}

/// [`bench`] with a declared per-iteration work-item count, so the
/// report (and the emitted `BENCH_*.json` artifacts) carry a
/// throughput figure — items per second at the **median** sample, the
/// raw-speed number the perf budgets track (candidates/sec for sweep
/// benches, ops/sec for oracle benches).
pub fn bench_items<F: FnMut()>(
    name: &str,
    warmup: usize,
    samples: usize,
    items_per_iter: usize,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t = Instant::now();
        f();
        out.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let r = BenchResult { name: name.to_string(), samples_ms: out, items_per_iter };
    println!("{}", r.report());
    r
}

/// Time one invocation (for long-running whole-experiment benches).
pub fn once<F: FnOnce()>(name: &str, f: F) -> BenchResult {
    let t = Instant::now();
    f();
    let r = BenchResult {
        name: name.to_string(),
        samples_ms: vec![t.elapsed().as_secs_f64() * 1e3],
        items_per_iter: 0,
    };
    println!("{}", r.report());
    r
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// The CLI's shared "oracle: …" stats line: every subcommand that
/// prices through a [`crate::perfdb::MemoOracle`] reports the same
/// ops-priced rate and memo hit share (`search`, `sweep`, `plan`,
/// `validate`, `replan` all print this one formatter's output).
pub fn oracle_line(memo_hits: u64, memo_misses: u64, elapsed_s: f64) -> String {
    let ops = memo_hits + memo_misses;
    format!(
        "oracle: {} ops priced ({:.0} ops/s), memo hit rate {:.1}% ({} hits, {} misses)",
        ops,
        ops as f64 / elapsed_s.max(1e-9),
        100.0 * memo_hits as f64 / (ops.max(1)) as f64,
        memo_hits,
        memo_misses
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures() {
        let r = bench("noop-spin", 1, 5, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert_eq!(r.samples_ms.len(), 5);
        assert!(r.median_ms() >= 0.0);
        assert!(r.p95_ms() >= r.median_ms());
    }

    #[test]
    fn oracle_line_format_is_stable() {
        let l = oracle_line(75, 25, 2.0);
        assert_eq!(l, "oracle: 100 ops priced (50 ops/s), memo hit rate 75.0% (75 hits, 25 misses)");
        // Zero ops must not divide by zero.
        assert!(oracle_line(0, 0, 0.0).contains("0 ops priced"));
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_ms(0.0005).contains("µs"));
        assert!(fmt_ms(5.0).contains("ms"));
        assert!(fmt_ms(5000.0).contains(" s"));
    }

    #[test]
    fn throughput_from_item_count() {
        let r = bench_items("spin-items", 0, 3, 1000, || {
            let mut s = 0u64;
            for i in 0..20_000u64 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        let thru = r.throughput_per_s().expect("item count was declared");
        assert!((thru - 1000.0 / (r.median_ms() / 1e3)).abs() < 1e-6);
        assert!(r.report().contains("thrpt:"));
        // No item count → no throughput claim in the report.
        let plain = bench("spin-plain", 0, 2, || {
            black_box(0u64);
        });
        assert!(plain.throughput_per_s().is_none());
        assert!(!plain.report().contains("thrpt:"));
        assert!(fmt_count(1_500_000.0).ends_with('M'));
        assert!(fmt_count(2_500.0).ends_with('k'));
    }
}

//! Small shared utilities: deterministic RNG, axis transforms, stats.

pub mod bench;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;

/// Round `x` up to the next multiple of `m` (m > 0).
pub fn ceil_div(x: u64, m: u64) -> u64 {
    debug_assert!(m > 0);
    x.div_ceil(m)
}

/// `log2` of a positive value, as f64.
pub fn log2f(x: f64) -> f64 {
    debug_assert!(x > 0.0, "log2 of non-positive value {x}");
    x.log2()
}

/// Logarithmically spaced values from `lo` to `hi` inclusive (`n >= 2`).
pub fn logspace(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    assert!(n >= 2 && lo > 0.0 && hi > lo);
    let (l, h) = (lo.ln(), hi.ln());
    (0..n)
        .map(|i| (l + (h - l) * i as f64 / (n - 1) as f64).exp())
        .collect()
}

/// Linearly spaced values from `lo` to `hi` inclusive (`n >= 2`).
pub fn linspace(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    assert!(n >= 2);
    (0..n)
        .map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn logspace_endpoints() {
        let v = logspace(1.0, 1024.0, 11);
        assert!((v[0] - 1.0).abs() < 1e-9);
        assert!((v[10] - 1024.0).abs() < 1e-6);
        assert!((v[5] - 32.0).abs() < 1e-6);
    }

    #[test]
    fn linspace_endpoints() {
        let v = linspace(0.0, 10.0, 5);
        assert_eq!(v, vec![0.0, 2.5, 5.0, 7.5, 10.0]);
    }
}

//! Minimal dependency-free JSON (this build environment has no network,
//! and `serde` is not in the vendored crate set — see DESIGN.md).
//!
//! Supports the full JSON data model with a recursive-descent parser and
//! a compact writer. Used for: perf-database persistence, the AOT
//! `artifacts/manifest.json` shape contract, workload descriptors, and
//! the config-search service wire protocol (JSON-lines over TCP).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are f64 (adequate for all our payloads).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if not an object).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Field access helpers that produce readable errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a number"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a string"))
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|j| j.as_f64()).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|j| j.as_str()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|j| j.as_bool()).unwrap_or(default)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn arr<I: IntoIterator<Item = Json>>(it: I) -> Json {
    Json::Arr(it.into_iter().collect())
}
pub fn farr(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
}

/// Parse a JSON document.
pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        anyhow::bail!("trailing characters at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.peek()? as char
            )
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => anyhow::bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => anyhow::bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                anyhow::bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => anyhow::bail!("invalid escape at byte {}", self.i),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = txt
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid number '{txt}' at byte {start}"))?;
        Ok(Json::Num(n))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": 2.5}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.req_f64("a").unwrap(), 1.0);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().req_f64("d").unwrap(), 2.5);
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn numbers() {
        for (txt, want) in [
            ("0", 0.0),
            ("-1.5", -1.5),
            ("1e3", 1000.0),
            ("2.5E-2", 0.025),
        ] {
            assert_eq!(parse(txt).unwrap().as_f64().unwrap(), want);
        }
    }

    #[test]
    fn strings_and_escapes() {
        let v = parse(r#""aéb\t""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\u{e9}b\t");
        // Round-trip non-ascii.
        let j = Json::Str("héllo \"wörld\"".to_string());
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{'a': 1}").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn builder_helpers() {
        let mut o = Json::obj();
        o.set("x", num(1.0)).set("y", s("z")).set("a", farr(&[1.0, 2.0]));
        let p = parse(&o.to_string()).unwrap();
        assert_eq!(p.req_str("y").unwrap(), "z");
    }
}

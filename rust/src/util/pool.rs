//! Shared worker pool with an atomic-cursor job queue.
//!
//! The candidate-evaluation engine needs good load balance: disaggregated
//! pool pricing costs far more per job than an aggregated estimate, so
//! static chunking (the seed implementation, kept as
//! [`crate::search::TaskRunner::run_baseline`]) leaves workers idle while
//! one chunk of expensive jobs drains. Here every worker pulls the next
//! job index from one shared atomic cursor — work-stealing degenerated to
//! its simplest correct form, which is all a CPU-bound fork/join sweep
//! needs. Results are returned **in input order** regardless of thread
//! interleaving, and a panic in any job propagates to the caller after
//! the scope joins.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Map `f` over `items` on `threads` OS threads (0 = available
/// parallelism), pulling jobs from a shared atomic cursor. Returns one
/// result per item, in input order. Panics in `f` propagate.
pub fn scoped_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = effective_threads(threads, n);
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let cursor = AtomicUsize::new(0);
    let worker = |_wid: usize| {
        let mut out: Vec<(usize, R)> = Vec::new();
        loop {
            let i = cursor.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            out.push((i, f(i, &items[i])));
        }
        out
    };

    let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    std::thread::scope(|s| {
        let worker = &worker;
        let handles: Vec<_> = (0..threads).map(|w| s.spawn(move || worker(w))).collect();
        for h in handles {
            match h.join() {
                Ok(part) => {
                    for (i, r) in part {
                        slots[i] = Some(r);
                    }
                }
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    slots
        .into_iter()
        .map(|r| r.expect("worker pool lost a job result"))
        .collect()
}

/// Resolve a thread-count request against the job count.
pub fn effective_threads(requested: usize, jobs: usize) -> usize {
    let hw = if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    };
    hw.min(jobs).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn empty_input() {
        let out: Vec<u32> = scoped_map(&[] as &[u32], 4, |_, x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn results_in_input_order_any_thread_count() {
        let items: Vec<u64> = (0..257).collect();
        for threads in [1usize, 2, 3, 8, 64] {
            let out = scoped_map(&items, threads, |i, x| {
                // Skew per-job cost so interleaving actually varies.
                let mut acc = *x;
                for k in 0..(x % 7) * 1000 {
                    acc = acc.wrapping_add(std::hint::black_box(k));
                }
                (i as u64, acc.wrapping_sub(acc) + x * 2)
            });
            assert_eq!(out.len(), items.len());
            for (i, (idx, v)) in out.iter().enumerate() {
                assert_eq!(*idx, i as u64, "threads={threads}");
                assert_eq!(*v, items[i] * 2, "threads={threads}");
            }
        }
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let counter = AtomicUsize::new(0);
        let items: Vec<u32> = (0..1000).collect();
        let out = scoped_map(&items, 8, |_, x| {
            counter.fetch_add(1, Ordering::Relaxed);
            *x
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
        assert_eq!(out, items);
    }

    #[test]
    fn panic_propagates() {
        let items: Vec<u32> = (0..64).collect();
        let res = std::panic::catch_unwind(|| {
            scoped_map(&items, 4, |_, x| {
                if *x == 33 {
                    panic!("job 33 exploded");
                }
                *x
            })
        });
        assert!(res.is_err(), "panic in a job must reach the caller");
    }

    #[test]
    fn effective_threads_resolution() {
        assert_eq!(effective_threads(4, 2), 2);
        assert_eq!(effective_threads(4, 100), 4);
        assert_eq!(effective_threads(1, 100), 1);
        assert!(effective_threads(0, 100) >= 1);
        assert_eq!(effective_threads(0, 1), 1);
    }
}

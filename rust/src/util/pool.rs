//! Shared worker pool with an atomic-cursor job queue.
//!
//! The candidate-evaluation engine needs good load balance: disaggregated
//! pool pricing costs far more per job than an aggregated estimate, so
//! static chunking (the seed implementation, kept as
//! [`crate::search::TaskRunner::run_baseline`]) leaves workers idle while
//! one chunk of expensive jobs drains. Here every worker pulls the next
//! job index from one shared atomic cursor — work-stealing degenerated to
//! its simplest correct form, which is all a CPU-bound fork/join sweep
//! needs. Results are returned **in input order** regardless of thread
//! interleaving, and a panic in any job propagates to the caller after
//! the scope joins.
//!
//! Two refinements for the pricing hot path:
//!
//! * **chunked cursor grabs** — workers `fetch_add` a chunk of `K`
//!   indices, not 1, cutting cacheline ping-pong on the shared cursor
//!   by K×; the tail chunk is clamped to the item count so the last
//!   partial chunk is never skipped;
//! * **per-worker state** ([`scoped_map_states`]) — each worker builds
//!   a private state object (thread-local memo, frontier accumulator)
//!   at spawn; the states come back **in worker-id order** at join so
//!   callers can merge them deterministically.

use std::sync::atomic::{AtomicUsize, Ordering};

/// The shared fork/join core: map `f` over `items` on `threads`
/// threads, pulling `chunk`-sized index ranges from one atomic cursor.
/// Per-worker results are preallocated at the expected share
/// (`n / threads + 1`). Returns (results in input order, per-worker
/// states in worker-id order).
fn run_pool<T, R, S, I, F>(
    items: &[T],
    threads: usize,
    chunk: usize,
    init: I,
    f: F,
) -> (Vec<R>, Vec<S>)
where
    T: Sync,
    R: Send,
    S: Send,
    I: Fn(usize) -> S + Sync,
    F: Fn(&S, usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return (Vec::new(), Vec::new());
    }
    let threads = effective_threads(threads, n);
    let chunk = chunk.max(1);
    if threads <= 1 {
        let state = init(0);
        let out = items.iter().enumerate().map(|(i, t)| f(&state, i, t)).collect();
        return (out, vec![state]);
    }

    let cursor = AtomicUsize::new(0);
    let worker = |wid: usize| {
        let state = init(wid);
        let mut out: Vec<(usize, R)> = Vec::with_capacity(n / threads + 1);
        loop {
            let start = cursor.fetch_add(chunk, Ordering::Relaxed);
            if start >= n {
                break;
            }
            // Clamp the tail: the final grab may reach past `n`, but
            // its in-range prefix (the last partial chunk) still runs.
            let end = (start + chunk).min(n);
            for i in start..end {
                out.push((i, f(&state, i, &items[i])));
            }
        }
        (out, state)
    };

    let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    let mut states: Vec<S> = Vec::with_capacity(threads);
    std::thread::scope(|s| {
        let worker = &worker;
        let handles: Vec<_> = (0..threads).map(|w| s.spawn(move || worker(w))).collect();
        // Joined in spawn order == worker-id order.
        for h in handles {
            match h.join() {
                Ok((part, state)) => {
                    for (i, r) in part {
                        slots[i] = Some(r);
                    }
                    states.push(state);
                }
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    let out = slots
        .into_iter()
        .map(|r| r.expect("worker pool lost a job result"))
        .collect();
    (out, states)
}

/// Map `f` over `items` on `threads` OS threads (0 = available
/// parallelism), pulling jobs from a shared atomic cursor. Returns one
/// result per item, in input order. Panics in `f` propagate.
pub fn scoped_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    run_pool(items, threads, 1, |_| (), |_, i, t| f(i, t)).0
}

/// [`scoped_map`] with per-worker state and chunked cursor grabs: each
/// worker calls `init(worker_id)` once at spawn and hands the state to
/// every job it runs (`f(&state, index, item)`); states are returned in
/// worker-id order so the caller can fold them deterministically. This
/// is how the search runner gives each worker a thread-local memo and a
/// private frontier accumulator.
pub fn scoped_map_states<T, R, S, I, F>(
    items: &[T],
    threads: usize,
    chunk: usize,
    init: I,
    f: F,
) -> (Vec<R>, Vec<S>)
where
    T: Sync,
    R: Send,
    S: Send,
    I: Fn(usize) -> S + Sync,
    F: Fn(&S, usize, &T) -> R + Sync,
{
    run_pool(items, threads, chunk, init, f)
}

/// Resolve a thread-count request against the job count.
pub fn effective_threads(requested: usize, jobs: usize) -> usize {
    let hw = if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    };
    hw.min(jobs).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn empty_input() {
        let out: Vec<u32> = scoped_map(&[] as &[u32], 4, |_, x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn results_in_input_order_any_thread_count() {
        let items: Vec<u64> = (0..257).collect();
        for threads in [1usize, 2, 3, 8, 64] {
            let out = scoped_map(&items, threads, |i, x| {
                // Skew per-job cost so interleaving actually varies.
                let mut acc = *x;
                for k in 0..(x % 7) * 1000 {
                    acc = acc.wrapping_add(std::hint::black_box(k));
                }
                (i as u64, acc.wrapping_sub(acc) + x * 2)
            });
            assert_eq!(out.len(), items.len());
            for (i, (idx, v)) in out.iter().enumerate() {
                assert_eq!(*idx, i as u64, "threads={threads}");
                assert_eq!(*v, items[i] * 2, "threads={threads}");
            }
        }
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let counter = AtomicUsize::new(0);
        let items: Vec<u32> = (0..1000).collect();
        let out = scoped_map(&items, 8, |_, x| {
            counter.fetch_add(1, Ordering::Relaxed);
            *x
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
        assert_eq!(out, items);
    }

    #[test]
    fn chunked_tail_never_skips_the_last_partial_chunk() {
        // n deliberately not divisible by chunk × threads (and not by
        // chunk alone): 103 = 4·25 + 3 — the final grab covers indices
        // 100..103 only. Every item must still run exactly once, in
        // order, for a spread of (threads, chunk) combinations.
        for (threads, chunk) in [(3usize, 4usize), (4, 8), (2, 7), (8, 16), (5, 1)] {
            let items: Vec<u64> = (0..103).collect();
            let counter = AtomicUsize::new(0);
            let (out, states) = scoped_map_states(
                &items,
                threads,
                chunk,
                |wid| wid,
                |_, i, x| {
                    counter.fetch_add(1, Ordering::Relaxed);
                    (i, *x * 3)
                },
            );
            assert_eq!(
                counter.load(Ordering::Relaxed),
                items.len(),
                "threads={threads} chunk={chunk}"
            );
            for (i, (idx, v)) in out.iter().enumerate() {
                assert_eq!(*idx, i, "threads={threads} chunk={chunk}");
                assert_eq!(*v, items[i] * 3, "threads={threads} chunk={chunk}");
            }
            // States arrive in worker-id order.
            assert_eq!(states, (0..states.len()).collect::<Vec<_>>());
            assert!(states.len() <= threads.min(items.len()));
        }
    }

    #[test]
    fn per_worker_state_is_private_and_merged_in_id_order() {
        let items: Vec<u64> = (0..500).collect();
        let (out, states) = scoped_map_states(
            &items,
            4,
            8,
            |wid| (wid, AtomicUsize::new(0)),
            |state, _, x| {
                state.1.fetch_add(*x as usize, Ordering::Relaxed);
                *x
            },
        );
        assert_eq!(out, items);
        let ids: Vec<usize> = states.iter().map(|s| s.0).collect();
        assert_eq!(ids, (0..states.len()).collect::<Vec<_>>());
        // Every contribution landed in exactly one worker's state.
        let total: usize = states.iter().map(|s| s.1.load(Ordering::Relaxed)).sum();
        assert_eq!(total, (0..500usize).sum::<usize>());
    }

    #[test]
    fn panic_propagates() {
        let items: Vec<u32> = (0..64).collect();
        let res = std::panic::catch_unwind(|| {
            scoped_map(&items, 4, |_, x| {
                if *x == 33 {
                    panic!("job 33 exploded");
                }
                *x
            })
        });
        assert!(res.is_err(), "panic in a job must reach the caller");
    }

    #[test]
    fn effective_threads_resolution() {
        assert_eq!(effective_threads(4, 2), 2);
        assert_eq!(effective_threads(4, 100), 4);
        assert_eq!(effective_threads(1, 100), 1);
        assert!(effective_threads(0, 100) >= 1);
        assert_eq!(effective_threads(0, 1), 1);
    }
}

//! Deterministic, dependency-free RNG (splitmix64 + xoshiro256**).
//!
//! The coordinator owns all randomness (measurement noise in
//! [`crate::silicon`], scheduler jitter in [`crate::simulator`], uniform
//! samples fed to the MoE power-law kernel) so every experiment is
//! reproducible from a single seed.

/// xoshiro256** seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller variate.
    spare: Option<f64>,
}

impl Rng {
    /// Create an RNG from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
            spare: None,
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1) — never exactly 0 (safe for log/power laws).
    pub fn f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64 + 0.5) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift; bias negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let u1 = self.f64_open();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    /// Lognormal multiplicative noise with standard deviation ~`sigma`
    /// (mean-one for small sigma): `exp(sigma * N(0,1) - sigma^2/2)`.
    pub fn noise(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal() - 0.5 * sigma * sigma).exp()
    }

    /// Exponential variate with the given rate (for Poisson arrivals).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.f64_open().ln() / rate
    }

    /// Fork a child RNG for an independent stream.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            m1 += v;
            m2 += v * v;
        }
        assert!((m1 / n as f64).abs() < 0.02);
        assert!((m2 / n as f64 - 1.0).abs() < 0.05);
    }

    #[test]
    fn noise_mean_one() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += r.noise(0.05);
        }
        assert!((sum / n as f64 - 1.0).abs() < 0.01);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}

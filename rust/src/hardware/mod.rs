//! GPU and cluster specifications (paper §4.4 "hardware specifications:
//! memory bandwidth, compute throughput, interconnect bandwidth").
//!
//! Public datasheet numbers for the platforms the paper's database covers
//! (Ampere → Blackwell). Crossover behaviour (agg vs disagg, TP vs EP)
//! is driven by the *ratios* of these constants, which is why the
//! synthetic-silicon substitution preserves the paper's conclusions
//! (DESIGN.md).

use crate::models::Dtype;

/// A single GPU's performance envelope.
#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    /// Canonical platform id. This is also the on-disk key the
    /// calibration layer binds to: measurement sets live at
    /// `artifacts/measurements/<name>/` and a `CalibrationArtifact`
    /// only composes over databases profiled for the same `name`
    /// (`crate::perfdb::measure`, DESIGN.md §6) — renaming a preset is
    /// a data-format break.
    pub name: &'static str,
    /// HBM capacity in GiB.
    pub mem_gib: f64,
    /// HBM bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// Dense tensor-core TFLOPS at fp16.
    pub fp16_tflops: f64,
    /// Dense tensor-core TFLOPS at fp8 (0 = unsupported).
    pub fp8_tflops: f64,
    /// Dense int8 TOPS.
    pub int8_tops: f64,
    /// NVLink bandwidth per GPU (unidirectional aggregate), GB/s.
    pub nvlink_gbs: f64,
    /// Streaming multiprocessor count (wave quantization granularity).
    pub sm_count: u32,
    /// Kernel launch overhead, microseconds.
    pub launch_us: f64,
    /// Representative public-cloud on-demand list price, USD per
    /// GPU-hour (rounded; the capacity planner prices schedules with
    /// it, and only the *ratios* between platforms drive its
    /// heterogeneous-fleet decisions).
    pub usd_per_hour: f64,
}

impl GpuSpec {
    /// Peak dense TFLOPS for a dtype (int4 runs on the int8 path at 2×
    /// weight-bandwidth advantage but same MACs on these parts).
    pub fn tflops(&self, dt: Dtype) -> f64 {
        match dt {
            Dtype::Fp16 => self.fp16_tflops,
            Dtype::Fp8 => {
                if self.fp8_tflops > 0.0 {
                    self.fp8_tflops
                } else {
                    self.int8_tops // Ampere: fall back to int8 path
                }
            }
            Dtype::Int8 | Dtype::Int4 => self.int8_tops,
        }
    }

    pub fn supports(&self, dt: Dtype) -> bool {
        !matches!(dt, Dtype::Fp8) || self.fp8_tflops > 0.0
    }

    /// The dtype a profiling campaign / engine sweep should default to
    /// on this part: FP8 where the tensor cores support it, FP16
    /// otherwise (Ampere). One definition shared by the CLI, the
    /// service and the benches so mixed-generation fleets price
    /// identically on every surface.
    pub fn preferred_kv_dtype(&self) -> Dtype {
        if self.supports(Dtype::Fp8) {
            Dtype::Fp8
        } else {
            Dtype::Fp16
        }
    }

    pub fn mem_bytes(&self) -> f64 {
        self.mem_gib * 1024.0 * 1024.0 * 1024.0
    }
}

/// NVIDIA A100 SXM4 80GB (Ampere).
pub fn a100_sxm() -> GpuSpec {
    GpuSpec {
        name: "a100-sxm",
        mem_gib: 80.0,
        mem_bw_gbs: 2039.0,
        fp16_tflops: 312.0,
        fp8_tflops: 0.0,
        int8_tops: 624.0,
        nvlink_gbs: 300.0,
        sm_count: 108,
        launch_us: 4.0,
        usd_per_hour: 2.50,
    }
}

/// NVIDIA H100 SXM5 80GB (Hopper) — paper §5.1 testbed.
pub fn h100_sxm() -> GpuSpec {
    GpuSpec {
        name: "h100-sxm",
        mem_gib: 80.0,
        mem_bw_gbs: 3350.0,
        fp16_tflops: 989.0,
        fp8_tflops: 1979.0,
        int8_tops: 1979.0,
        nvlink_gbs: 450.0,
        sm_count: 132,
        launch_us: 3.0,
        usd_per_hour: 4.90,
    }
}

/// NVIDIA H200 SXM 141GB (Hopper refresh) — paper §5.4 / Fig 1 testbed.
pub fn h200_sxm() -> GpuSpec {
    GpuSpec {
        name: "h200-sxm",
        mem_gib: 141.0,
        mem_bw_gbs: 4800.0,
        fp16_tflops: 989.0,
        fp8_tflops: 1979.0,
        int8_tops: 1979.0,
        nvlink_gbs: 450.0,
        sm_count: 132,
        launch_us: 3.0,
        usd_per_hour: 6.30,
    }
}

/// NVIDIA B200 192GB (Blackwell).
pub fn b200() -> GpuSpec {
    GpuSpec {
        name: "b200",
        mem_gib: 192.0,
        mem_bw_gbs: 8000.0,
        fp16_tflops: 2250.0,
        fp8_tflops: 4500.0,
        int8_tops: 4500.0,
        nvlink_gbs: 900.0,
        sm_count: 148,
        launch_us: 3.0,
        usd_per_hour: 11.00,
    }
}

pub fn gpu_by_name(name: &str) -> Option<GpuSpec> {
    match name.to_ascii_lowercase().as_str() {
        "a100" | "a100-sxm" => Some(a100_sxm()),
        "h100" | "h100-sxm" => Some(h100_sxm()),
        "h200" | "h200-sxm" => Some(h200_sxm()),
        "b200" => Some(b200()),
        _ => None,
    }
}

/// Link class a collective runs over — decides effective bandwidth.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkKind {
    /// Intra-node NVLink/NVSwitch domain.
    NvLink,
    /// Cross-node InfiniBand fabric.
    InfiniBand,
}

/// A homogeneous cluster: `num_nodes` nodes of `gpus_per_node` GPUs.
#[derive(Clone, Copy, Debug)]
pub struct ClusterSpec {
    pub gpu: GpuSpec,
    pub gpus_per_node: u32,
    pub num_nodes: u32,
    /// Per-GPU InfiniBand bandwidth (unidirectional), GB/s.
    /// 400 Gb/s NDR per GPU = 50 GB/s.
    pub ib_gbs: f64,
    /// Base latency of an IB hop, microseconds.
    pub ib_latency_us: f64,
    /// Base latency of an NVLink hop, microseconds.
    pub nvlink_latency_us: f64,
}

impl ClusterSpec {
    pub fn new(gpu: GpuSpec, gpus_per_node: u32, num_nodes: u32) -> Self {
        ClusterSpec {
            gpu,
            gpus_per_node,
            num_nodes,
            ib_gbs: 50.0,
            ib_latency_us: 8.0,
            nvlink_latency_us: 2.0,
        }
    }

    pub fn total_gpus(&self) -> u32 {
        self.gpus_per_node * self.num_nodes
    }

    /// On-demand price of the whole cluster, USD per hour.
    pub fn usd_per_hour(&self) -> f64 {
        self.gpu.usd_per_hour * self.total_gpus() as f64
    }

    /// Which link class a `gpus`-wide collective uses.
    pub fn link_for(&self, gpus: u32) -> LinkKind {
        if gpus <= self.gpus_per_node {
            LinkKind::NvLink
        } else {
            LinkKind::InfiniBand
        }
    }

    /// Effective point-to-point bandwidth between two specific GPUs.
    pub fn p2p_bw_gbs(&self, link: LinkKind) -> f64 {
        match link {
            LinkKind::NvLink => self.gpu.nvlink_gbs,
            LinkKind::InfiniBand => self.ib_gbs,
        }
    }

    pub fn link_latency_us(&self, link: LinkKind) -> f64 {
        match link {
            LinkKind::NvLink => self.nvlink_latency_us,
            LinkKind::InfiniBand => self.ib_latency_us,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry() {
        for n in ["a100", "h100", "h200", "b200"] {
            assert!(gpu_by_name(n).is_some());
        }
        assert!(gpu_by_name("v100").is_none());
    }

    #[test]
    fn dtype_support() {
        assert!(!a100_sxm().supports(Dtype::Fp8));
        assert!(h100_sxm().supports(Dtype::Fp8));
        assert_eq!(h100_sxm().tflops(Dtype::Fp8), 1979.0);
        // Ampere fp8 request falls back to the int8 path.
        assert_eq!(a100_sxm().tflops(Dtype::Fp8), 624.0);
        // Profiling/sweep default follows tensor-core support.
        assert_eq!(h100_sxm().preferred_kv_dtype(), Dtype::Fp8);
        assert_eq!(a100_sxm().preferred_kv_dtype(), Dtype::Fp16);
    }

    #[test]
    fn cluster_topology() {
        let c = ClusterSpec::new(h100_sxm(), 8, 2);
        assert_eq!(c.total_gpus(), 16);
        assert_eq!(c.link_for(8), LinkKind::NvLink);
        assert_eq!(c.link_for(16), LinkKind::InfiniBand);
        assert!(c.p2p_bw_gbs(LinkKind::NvLink) > c.p2p_bw_gbs(LinkKind::InfiniBand));
    }

    #[test]
    fn pricing_covers_every_preset_and_prices_clusters() {
        for n in ["a100", "h100", "h200", "b200"] {
            assert!(gpu_by_name(n).unwrap().usd_per_hour > 0.0, "{n} has no price");
        }
        // Newer platforms list higher (the planner trades that against
        // their higher throughput).
        assert!(a100_sxm().usd_per_hour < h100_sxm().usd_per_hour);
        assert!(h100_sxm().usd_per_hour < h200_sxm().usd_per_hour);
        assert!(h200_sxm().usd_per_hour < b200().usd_per_hour);
        // A 2-node 8-GPU/node H100 cluster prices as 16 GPU-hours/hour.
        let c = ClusterSpec::new(h100_sxm(), 8, 2);
        assert_eq!(c.usd_per_hour(), 16.0 * h100_sxm().usd_per_hour);
    }

    #[test]
    fn h200_vs_h100() {
        // Same compute, more/faster memory — the ratio that drives
        // decode-heavy configs toward H200.
        let (a, b) = (h100_sxm(), h200_sxm());
        assert_eq!(a.fp16_tflops, b.fp16_tflops);
        assert!(b.mem_bw_gbs > a.mem_bw_gbs);
        assert!(b.mem_gib > a.mem_gib);
    }
}

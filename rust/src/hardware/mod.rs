//! GPU and cluster specifications (paper §4.4 "hardware specifications:
//! memory bandwidth, compute throughput, interconnect bandwidth").
//!
//! Public datasheet numbers for the platforms the paper's database covers
//! (Ampere → Blackwell). Crossover behaviour (agg vs disagg, TP vs EP)
//! is driven by the *ratios* of these constants, which is why the
//! synthetic-silicon substitution preserves the paper's conclusions
//! (DESIGN.md).

use crate::models::Dtype;
use crate::topology::FabricSpec;

/// A single GPU's performance envelope.
#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    /// Canonical platform id. This is also the on-disk key the
    /// calibration layer binds to: measurement sets live at
    /// `artifacts/measurements/<name>/` and a `CalibrationArtifact`
    /// only composes over databases profiled for the same `name`
    /// (`crate::perfdb::measure`, DESIGN.md §6) — renaming a preset is
    /// a data-format break.
    pub name: &'static str,
    /// HBM capacity in GiB.
    pub mem_gib: f64,
    /// HBM bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// Dense tensor-core TFLOPS at fp16.
    pub fp16_tflops: f64,
    /// Dense tensor-core TFLOPS at fp8 (0 = unsupported).
    pub fp8_tflops: f64,
    /// Dense int8 TOPS.
    pub int8_tops: f64,
    /// NVLink bandwidth per GPU (unidirectional aggregate), GB/s.
    pub nvlink_gbs: f64,
    /// Streaming multiprocessor count (wave quantization granularity).
    pub sm_count: u32,
    /// Kernel launch overhead, microseconds.
    pub launch_us: f64,
    /// Representative public-cloud on-demand list price, USD per
    /// GPU-hour (rounded; the capacity planner prices schedules with
    /// it, and only the *ratios* between platforms drive its
    /// heterogeneous-fleet decisions).
    pub usd_per_hour: f64,
}

impl GpuSpec {
    /// Peak dense TFLOPS for a dtype (int4 runs on the int8 path at 2×
    /// weight-bandwidth advantage but same MACs on these parts).
    pub fn tflops(&self, dt: Dtype) -> f64 {
        match dt {
            Dtype::Fp16 => self.fp16_tflops,
            Dtype::Fp8 => {
                if self.fp8_tflops > 0.0 {
                    self.fp8_tflops
                } else {
                    self.int8_tops // Ampere: fall back to int8 path
                }
            }
            Dtype::Int8 | Dtype::Int4 => self.int8_tops,
        }
    }

    pub fn supports(&self, dt: Dtype) -> bool {
        !matches!(dt, Dtype::Fp8) || self.fp8_tflops > 0.0
    }

    /// The dtype a profiling campaign / engine sweep should default to
    /// on this part: FP8 where the tensor cores support it, FP16
    /// otherwise (Ampere). One definition shared by the CLI, the
    /// service and the benches so mixed-generation fleets price
    /// identically on every surface.
    pub fn preferred_kv_dtype(&self) -> Dtype {
        if self.supports(Dtype::Fp8) {
            Dtype::Fp8
        } else {
            Dtype::Fp16
        }
    }

    pub fn mem_bytes(&self) -> f64 {
        self.mem_gib * 1024.0 * 1024.0 * 1024.0
    }
}

/// NVIDIA A100 SXM4 80GB (Ampere).
pub fn a100_sxm() -> GpuSpec {
    GpuSpec {
        name: "a100-sxm",
        mem_gib: 80.0,
        mem_bw_gbs: 2039.0,
        fp16_tflops: 312.0,
        fp8_tflops: 0.0,
        int8_tops: 624.0,
        nvlink_gbs: 300.0,
        sm_count: 108,
        launch_us: 4.0,
        usd_per_hour: 2.50,
    }
}

/// NVIDIA H100 SXM5 80GB (Hopper) — paper §5.1 testbed.
pub fn h100_sxm() -> GpuSpec {
    GpuSpec {
        name: "h100-sxm",
        mem_gib: 80.0,
        mem_bw_gbs: 3350.0,
        fp16_tflops: 989.0,
        fp8_tflops: 1979.0,
        int8_tops: 1979.0,
        nvlink_gbs: 450.0,
        sm_count: 132,
        launch_us: 3.0,
        usd_per_hour: 4.90,
    }
}

/// NVIDIA H200 SXM 141GB (Hopper refresh) — paper §5.4 / Fig 1 testbed.
pub fn h200_sxm() -> GpuSpec {
    GpuSpec {
        name: "h200-sxm",
        mem_gib: 141.0,
        mem_bw_gbs: 4800.0,
        fp16_tflops: 989.0,
        fp8_tflops: 1979.0,
        int8_tops: 1979.0,
        nvlink_gbs: 450.0,
        sm_count: 132,
        launch_us: 3.0,
        usd_per_hour: 6.30,
    }
}

/// NVIDIA B200 192GB (Blackwell).
pub fn b200() -> GpuSpec {
    GpuSpec {
        name: "b200",
        mem_gib: 192.0,
        mem_bw_gbs: 8000.0,
        fp16_tflops: 2250.0,
        fp8_tflops: 4500.0,
        int8_tops: 4500.0,
        nvlink_gbs: 900.0,
        sm_count: 148,
        launch_us: 3.0,
        usd_per_hour: 11.00,
    }
}

/// NVIDIA B200 SXM 180GB (air-cooled HGX B200 board: slightly smaller
/// HBM stack and lower sustained clocks than the reference `b200`).
pub fn b200_sxm() -> GpuSpec {
    GpuSpec {
        name: "b200-sxm",
        mem_gib: 180.0,
        mem_bw_gbs: 7700.0,
        fp16_tflops: 2250.0,
        fp8_tflops: 4500.0,
        int8_tops: 4500.0,
        nvlink_gbs: 900.0,
        sm_count: 148,
        launch_us: 3.0,
        usd_per_hour: 10.50,
    }
}

/// NVIDIA GB200 (NVL72 rack, Blackwell + Grace): the liquid-cooled
/// part behind the `gb200-nvl72` wide-domain fabric preset — higher
/// sustained clocks and HBM3e than the air-cooled SXM boards.
pub fn gb200_nvl72() -> GpuSpec {
    GpuSpec {
        name: "gb200-nvl72",
        mem_gib: 186.0,
        mem_bw_gbs: 8000.0,
        fp16_tflops: 2450.0,
        fp8_tflops: 4900.0,
        int8_tops: 4900.0,
        nvlink_gbs: 900.0,
        sm_count: 148,
        launch_us: 3.0,
        usd_per_hour: 13.50,
    }
}

pub fn gpu_by_name(name: &str) -> Option<GpuSpec> {
    match name.to_ascii_lowercase().as_str() {
        "a100" | "a100-sxm" => Some(a100_sxm()),
        "h100" | "h100-sxm" => Some(h100_sxm()),
        "h200" | "h200-sxm" => Some(h200_sxm()),
        "b200" => Some(b200()),
        "b200-sxm" => Some(b200_sxm()),
        "gb200" | "gb200-nvl72" => Some(gb200_nvl72()),
        _ => None,
    }
}

/// One parsed fleet-leg spec: `GPU[@FABRIC]`.
#[derive(Clone, Debug)]
pub struct FleetLeg {
    pub gpu: GpuSpec,
    pub fabric: crate::topology::FabricSpec,
    /// The GPU token exactly as given (aliases preserved — service
    /// cache keys use it, so "h100" and "h100-sxm" behave as the
    /// caller wrote them).
    pub gpu_name: String,
    pub fabric_name: String,
}

/// Parse a fleet-leg spec `GPU[@FABRIC]` — one grammar shared by the
/// CLI's `--fleet` and the service's `"fleet"` entries, so the two
/// surfaces can never drift. A bare GPU name keeps the legacy flat
/// topology.
pub fn parse_fleet_leg(spec: &str, gpus_per_node: u32) -> anyhow::Result<FleetLeg> {
    let (gpu_name, fabric_name) = match spec.split_once('@') {
        Some((g, f)) => (g, f),
        None => (spec, "legacy"),
    };
    let gpu = gpu_by_name(gpu_name)
        .ok_or_else(|| anyhow::anyhow!("unknown gpu '{gpu_name}' in fleet"))?;
    let fabric = crate::topology::fabric::by_name(fabric_name, gpus_per_node)
        .ok_or_else(|| anyhow::anyhow!("unknown fabric '{fabric_name}' in fleet"))?;
    Ok(FleetLeg {
        gpu,
        fabric,
        gpu_name: gpu_name.to_string(),
        fabric_name: fabric_name.to_string(),
    })
}

/// Link class a collective runs over — decides effective bandwidth.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkKind {
    /// Intra-node NVLink/NVSwitch domain.
    NvLink,
    /// Cross-node InfiniBand fabric.
    InfiniBand,
}

/// A homogeneous cluster: `num_nodes` nodes of `gpus_per_node` GPUs,
/// wired by a [`FabricSpec`].
#[derive(Clone, Copy, Debug)]
pub struct ClusterSpec {
    pub gpu: GpuSpec,
    pub gpus_per_node: u32,
    pub num_nodes: u32,
    /// The interconnect tiers (NVLink-domain width, intra-node link,
    /// IB rails, optional pod fabric). [`ClusterSpec::new`] installs
    /// the legacy back-compat fabric — the seed's three hard-coded
    /// link constants, priced bit-for-bit by the legacy flat model.
    pub fabric: FabricSpec,
}

impl ClusterSpec {
    /// Back-compat constructor: the seed's flat NVLink-vs-IB topology
    /// (one 50 GB/s IB rail at 8 µs, NVLink at 2 µs, domain = node).
    /// Pinned equivalent to the pre-fabric behavior in
    /// `tests/topology.rs`.
    pub fn new(gpu: GpuSpec, gpus_per_node: u32, num_nodes: u32) -> Self {
        Self::with_fabric(gpu, gpus_per_node, num_nodes, FabricSpec::legacy(gpus_per_node))
    }

    /// A cluster wired by an explicit fabric (the `--fabric` path).
    pub fn with_fabric(
        gpu: GpuSpec,
        gpus_per_node: u32,
        num_nodes: u32,
        fabric: FabricSpec,
    ) -> Self {
        ClusterSpec { gpu, gpus_per_node, num_nodes, fabric }
    }

    pub fn total_gpus(&self) -> u32 {
        self.gpus_per_node * self.num_nodes
    }

    /// GPUs reachable over the fast (NVLink/PCIe) tier from one GPU —
    /// the NVLink-domain width clamped to the cluster.
    pub fn domain_size(&self) -> u32 {
        self.fabric.nvlink_domain.min(self.total_gpus()).max(1)
    }

    /// Intra-domain bandwidth, GB/s: the fabric's tier override (PCIe
    /// boxes) or the GPU's own NVLink datasheet number.
    pub fn nvlink_bw_gbs(&self) -> f64 {
        if self.fabric.intra_gbs > 0.0 {
            self.fabric.intra_gbs
        } else {
            self.gpu.nvlink_gbs
        }
    }

    /// On-demand price of the whole cluster, USD per hour.
    pub fn usd_per_hour(&self) -> f64 {
        self.gpu.usd_per_hour * self.total_gpus() as f64
    }

    /// Which link class a `gpus`-wide (naturally packed) collective
    /// uses.
    pub fn link_for(&self, gpus: u32) -> LinkKind {
        if gpus <= self.domain_size() {
            LinkKind::NvLink
        } else {
            LinkKind::InfiniBand
        }
    }

    /// Effective point-to-point bandwidth between two specific GPUs.
    pub fn p2p_bw_gbs(&self, link: LinkKind) -> f64 {
        match link {
            LinkKind::NvLink => self.nvlink_bw_gbs(),
            LinkKind::InfiniBand => self.fabric.rail_gbs,
        }
    }

    pub fn link_latency_us(&self, link: LinkKind) -> f64 {
        match link {
            LinkKind::NvLink => self.fabric.intra_latency_us,
            LinkKind::InfiniBand => self.fabric.ib_latency_us,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry() {
        for n in ["a100", "h100", "h200", "b200", "b200-sxm", "gb200-nvl72", "gb200"] {
            assert!(gpu_by_name(n).is_some(), "{n} missing from the registry");
        }
        assert!(gpu_by_name("v100").is_none());
    }

    #[test]
    fn blackwell_presets_have_matching_silicon_for_wide_fabrics() {
        // The gb200-nvl72 fabric preset needs silicon whose NVLink
        // tier actually spans the 72-GPU domain, and the SXM part must
        // stay the cheaper, slightly narrower board.
        let gb = gb200_nvl72();
        assert_eq!(gb.name, "gb200-nvl72");
        assert!(gb.supports(Dtype::Fp8) && gb.fp8_tflops > b200().fp8_tflops);
        assert!(gb.nvlink_gbs >= 900.0);
        let sxm = b200_sxm();
        assert!(sxm.mem_gib < b200().mem_gib);
        assert!(sxm.usd_per_hour < b200().usd_per_hour);
        assert!(gb.usd_per_hour > b200().usd_per_hour);
        // Cost accounting flows through clusters like every other part.
        let c = ClusterSpec::new(gb, 4, 18); // 72 GPUs, one NVL72 rack
        assert_eq!(c.total_gpus(), 72);
        assert_eq!(c.usd_per_hour(), 72.0 * gb.usd_per_hour);
    }

    #[test]
    fn fleet_leg_grammar() {
        let leg = parse_fleet_leg("h100", 8).unwrap();
        assert_eq!(leg.gpu.name, "h100-sxm");
        assert_eq!(leg.fabric.name, "legacy");
        assert_eq!(leg.gpu_name, "h100", "aliases are preserved verbatim");
        let leg = parse_fleet_leg("a100@a100-pcie", 8).unwrap();
        assert_eq!(leg.fabric.name, "a100-pcie");
        assert!(leg.fabric.placement_aware());
        assert!(parse_fleet_leg("h100@warp-fabric", 8).is_err());
        assert!(parse_fleet_leg("v100", 8).is_err());
    }

    #[test]
    fn dtype_support() {
        assert!(!a100_sxm().supports(Dtype::Fp8));
        assert!(h100_sxm().supports(Dtype::Fp8));
        assert_eq!(h100_sxm().tflops(Dtype::Fp8), 1979.0);
        // Ampere fp8 request falls back to the int8 path.
        assert_eq!(a100_sxm().tflops(Dtype::Fp8), 624.0);
        // Profiling/sweep default follows tensor-core support.
        assert_eq!(h100_sxm().preferred_kv_dtype(), Dtype::Fp8);
        assert_eq!(a100_sxm().preferred_kv_dtype(), Dtype::Fp16);
    }

    #[test]
    fn cluster_topology() {
        let c = ClusterSpec::new(h100_sxm(), 8, 2);
        assert_eq!(c.total_gpus(), 16);
        assert_eq!(c.link_for(8), LinkKind::NvLink);
        assert_eq!(c.link_for(16), LinkKind::InfiniBand);
        assert!(c.p2p_bw_gbs(LinkKind::NvLink) > c.p2p_bw_gbs(LinkKind::InfiniBand));
    }

    #[test]
    fn pricing_covers_every_preset_and_prices_clusters() {
        for n in ["a100", "h100", "h200", "b200", "b200-sxm", "gb200-nvl72"] {
            assert!(gpu_by_name(n).unwrap().usd_per_hour > 0.0, "{n} has no price");
        }
        // Newer platforms list higher (the planner trades that against
        // their higher throughput).
        assert!(a100_sxm().usd_per_hour < h100_sxm().usd_per_hour);
        assert!(h100_sxm().usd_per_hour < h200_sxm().usd_per_hour);
        assert!(h200_sxm().usd_per_hour < b200().usd_per_hour);
        // A 2-node 8-GPU/node H100 cluster prices as 16 GPU-hours/hour.
        let c = ClusterSpec::new(h100_sxm(), 8, 2);
        assert_eq!(c.usd_per_hour(), 16.0 * h100_sxm().usd_per_hour);
    }

    #[test]
    fn h200_vs_h100() {
        // Same compute, more/faster memory — the ratio that drives
        // decode-heavy configs toward H200.
        let (a, b) = (h100_sxm(), h200_sxm());
        assert_eq!(a.fp16_tflops, b.fp16_tflops);
        assert!(b.mem_bw_gbs > a.mem_bw_gbs);
        assert!(b.mem_gib > a.mem_gib);
    }
}

//! `artifacts/manifest.json` — the AOT shape contract emitted by
//! `python/compile/aot.py`, asserted here against the compiled-in
//! database geometry before any PJRT execution.

use std::path::Path;

use crate::perfdb::tables::{NUM_TABLES, NX, NY, NZ};
use crate::util::json;

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub num_tables: usize,
    pub grid: [usize; 3],
    pub query_batch: usize,
    pub query_batch_small: usize,
    pub moe_scenarios: usize,
    pub moe_experts: usize,
}

impl Manifest {
    pub fn load(path: &Path) -> anyhow::Result<Manifest> {
        let txt = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(&txt)
    }

    pub fn parse(txt: &str) -> anyhow::Result<Manifest> {
        let j = json::parse(txt)?;
        let interp = j.req("interp")?;
        let moe = j.req("moe_powerlaw")?;
        let grid = interp.req("grid")?.as_arr().ok_or_else(|| anyhow::anyhow!("bad grid"))?;
        anyhow::ensure!(grid.len() == 3, "grid must have 3 dims");
        Ok(Manifest {
            num_tables: interp.req_f64("num_tables")? as usize,
            grid: [
                grid[0].as_u64().unwrap_or(0) as usize,
                grid[1].as_u64().unwrap_or(0) as usize,
                grid[2].as_u64().unwrap_or(0) as usize,
            ],
            query_batch: interp.req_f64("query_batch")? as usize,
            query_batch_small: interp.f64_or("query_batch_small", 0.0) as usize,
            moe_scenarios: moe.req_f64("scenarios")? as usize,
            moe_experts: moe.req_f64("experts")? as usize,
        })
    }

    /// Assert agreement with the compiled-in geometry.
    pub fn check_contract(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.num_tables == NUM_TABLES && self.grid == [NX, NY, NZ],
            "artifact grid {:?}x{} != compiled {:?}x{} — rebuild artifacts",
            self.grid,
            self.num_tables,
            [NX, NY, NZ],
            NUM_TABLES
        );
        anyhow::ensure!(
            self.query_batch == super::QUERY_BATCH
                && self.moe_scenarios == super::MOE_SCENARIOS
                && self.moe_experts == super::MOE_EXPERTS,
            "artifact batch shapes changed — rebuild artifacts"
        );
        anyhow::ensure!(
            self.query_batch_small == 0 || self.query_batch_small == super::QUERY_BATCH_SMALL,
            "small-batch artifact shape changed — rebuild artifacts"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"{
      "interp": {"num_tables": 16, "grid": [32, 32, 16], "query_batch": 8192,
                 "query_batch_small": 256,
                 "inputs": ["grids","tids","coords"], "outputs": ["lat"]},
      "moe_powerlaw": {"scenarios": 256, "experts": 128,
                       "inputs": ["u","alpha","params"], "outputs": ["loads","imbalance"]}
    }"#;

    #[test]
    fn parse_and_check() {
        let m = Manifest::parse(GOOD).unwrap();
        assert_eq!(m.num_tables, 16);
        assert_eq!(m.grid, [32, 32, 16]);
        m.check_contract().unwrap();
    }

    #[test]
    fn contract_mismatch_rejected() {
        let bad = GOOD.replace("[32, 32, 16]", "[8, 8, 8]");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.check_contract().is_err());
    }

    #[test]
    fn missing_fields_rejected() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"interp": {}}"#).is_err());
    }
}

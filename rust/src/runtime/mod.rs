//! AOT runtime: load the JAX/Pallas-lowered HLO artifacts and execute
//! them on the PJRT CPU client from the Rust hot path.
//!
//! Python runs once at build time (`make artifacts`); this module makes
//! the binary self-contained afterwards. Interchange is HLO *text*
//! (xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id protos; the text
//! parser reassigns ids — see `python/compile/aot.py`).
//!
//! The PJRT client types hold raw pointers (!Send/!Sync), so the
//! executables live on a dedicated evaluator thread behind channels:
//! [`PjrtService`] is the thread-safe handle, and [`PjrtOracle`] adapts
//! it to the [`LatencyOracle`] interface used by the search path — this
//! is also exactly the dynamic-batching shape the config-search service
//! needs (many concurrent searches funneling queries into one executor).
//!
//! ## The `pjrt` and `xla` cargo features
//!
//! The PJRT path needs the `xla` crate (xla_extension bindings), which
//! is a heavyweight native dependency this offline build does not ship.
//! The real implementation is therefore gated behind the off-by-default
//! `xla` feature (which implies `pjrt`); both the default build and a
//! `--features pjrt` build substitute API-compatible stubs whose
//! `PjrtService::start` fails with a clear error, so every caller (CLI
//! `--pjrt`, service artifacts mode, artifact-gated tests and examples)
//! compiles unchanged and degrades gracefully to the native
//! interpolation path. CI builds the `--features pjrt` stub path
//! explicitly (feature-matrix job) so this gating cannot silently rot;
//! only `--features xla` requires vendoring the native crate.

pub mod manifest;

use std::path::Path;

use crate::ops::Op;
use crate::perfdb::tables::{query_for, GRID_LEN};
use crate::perfdb::{sol, LatencyOracle, PerfDatabase};

pub use manifest::Manifest;

/// Interp kernel AOT batch size (manifest `query_batch`).
pub const QUERY_BATCH: usize = 8192;
/// Small-batch interp variant (manifest `query_batch_small`) — candidate
/// step sweeps issue dozens of queries; padding them to 8192 wastes ~30x
/// gather work (§Perf iteration 1).
pub const QUERY_BATCH_SMALL: usize = 256;
/// MoE kernel AOT scenario count / expert width.
pub const MOE_SCENARIOS: usize = 256;
pub const MOE_EXPERTS: usize = 128;

// ---------------------------------------------------------------------------
// Stub implementation (any build without the `xla` feature — including
// `--features pjrt`, which CI exercises).
// ---------------------------------------------------------------------------

/// Thread-safe handle to the PJRT evaluator thread (stub: the default
/// build has no XLA runtime; `start` always errors).
#[cfg(not(feature = "xla"))]
pub struct PjrtService {
    _priv: (),
}

#[cfg(not(feature = "xla"))]
impl PjrtService {
    /// Load artifacts from `dir` and bind `grids` as the interpolation
    /// surface. The stub validates the payload shape, then reports that
    /// the runtime is unavailable.
    pub fn start(dir: &Path, grids: Vec<f32>) -> anyhow::Result<PjrtService> {
        anyhow::ensure!(grids.len() == GRID_LEN, "grid payload length {}", grids.len());
        anyhow::bail!(
            "PJRT runtime unavailable: aiconfigurator was built without the `xla` \
             feature (artifacts dir: {}). Rebuild with `--features xla` (implies pjrt) \
             and a vendored `xla` crate, or drop the --pjrt/artifacts option to use \
             the native interpolation path.",
            dir.display()
        )
    }

    /// Evaluate interpolation queries (stub: unreachable — `start` never
    /// returns a service).
    pub fn interp(&self, tids: &[i32], coords: &[f32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(coords.len() == tids.len() * 3, "coords shape mismatch");
        anyhow::bail!("PJRT runtime unavailable (built without the `xla` feature)")
    }

    /// Evaluate MoE power-law scenarios (stub).
    pub fn moe(
        &self,
        u: &[f32],
        alpha: &[f32],
        params: &[f32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let s = alpha.len();
        anyhow::ensure!(s <= MOE_SCENARIOS, "too many scenarios: {s}");
        anyhow::ensure!(u.len() == s * MOE_EXPERTS && params.len() == s * 3, "shape mismatch");
        anyhow::bail!("PJRT runtime unavailable (built without the `xla` feature)")
    }
}

/// [`LatencyOracle`] over the PJRT-executed Pallas interpolation kernel.
/// In the stub build it answers from the native database instead (it can
/// never actually be constructed, since [`PjrtService::start`] errors,
/// but call sites compile unchanged).
#[cfg(not(feature = "xla"))]
pub struct PjrtOracle<'a> {
    pub svc: &'a PjrtService,
    pub db: &'a PerfDatabase,
}

#[cfg(not(feature = "xla"))]
impl LatencyOracle for PjrtOracle<'_> {
    fn op_latency_us(&self, op: &Op) -> f64 {
        match query_for(op) {
            Some(q) => self.db.interp(&q) * q.scale,
            None => sol::latency_us(&self.db.cluster, op),
        }
    }
}

// ---------------------------------------------------------------------------
// Real implementation (requires the vendored `xla` crate).
// ---------------------------------------------------------------------------

#[cfg(feature = "xla")]
mod pjrt_impl {
    use std::path::{Path, PathBuf};
    use std::sync::mpsc;
    use std::sync::Mutex;

    use super::{Manifest, MOE_EXPERTS, MOE_SCENARIOS, QUERY_BATCH, QUERY_BATCH_SMALL};
    use crate::perfdb::tables::GRID_LEN;

    enum Job {
        Interp {
            tids: Vec<i32>,
            coords: Vec<f32>,
            resp: mpsc::Sender<anyhow::Result<Vec<f32>>>,
        },
        Moe {
            u: Vec<f32>,
            alpha: Vec<f32>,
            params: Vec<f32>,
            resp: mpsc::Sender<anyhow::Result<(Vec<f32>, Vec<f32>)>>,
        },
        Shutdown,
    }

    /// Thread-safe handle to the PJRT evaluator thread.
    pub struct PjrtService {
        tx: Mutex<mpsc::Sender<Job>>,
        handle: Option<std::thread::JoinHandle<()>>,
    }

    impl PjrtService {
        /// Load artifacts from `dir` (expects `interp.hlo.txt`,
        /// `moe_powerlaw.hlo.txt`, `manifest.json`) and bind the packed
        /// grids of `db` as the interpolation surface.
        pub fn start(dir: &Path, grids: Vec<f32>) -> anyhow::Result<PjrtService> {
            anyhow::ensure!(grids.len() == GRID_LEN, "grid payload length {}", grids.len());
            let m = Manifest::load(&dir.join("manifest.json"))?;
            m.check_contract()?;
            let interp_path: PathBuf = dir.join("interp.hlo.txt");
            let interp_small_path: PathBuf = dir.join("interp_small.hlo.txt");
            let moe_path: PathBuf = dir.join("moe_powerlaw.hlo.txt");
            anyhow::ensure!(interp_path.exists(), "missing {}", interp_path.display());
            anyhow::ensure!(moe_path.exists(), "missing {}", moe_path.display());

            let (tx, rx) = mpsc::channel::<Job>();
            let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
            let handle = std::thread::Builder::new()
                .name("pjrt-eval".into())
                .spawn(move || {
                    evaluator_thread(
                        rx,
                        ready_tx,
                        &interp_path,
                        &interp_small_path,
                        &moe_path,
                        grids,
                    )
                })?;
            ready_rx.recv()??;
            Ok(PjrtService { tx: Mutex::new(tx), handle: Some(handle) })
        }

        /// Evaluate interpolation queries. Arbitrary length — internally
        /// chunked and padded to the AOT batch (8192).
        pub fn interp(&self, tids: &[i32], coords: &[f32]) -> anyhow::Result<Vec<f32>> {
            anyhow::ensure!(coords.len() == tids.len() * 3, "coords shape mismatch");
            let (rtx, rrx) = mpsc::channel();
            self.tx
                .lock()
                .unwrap()
                .send(Job::Interp { tids: tids.to_vec(), coords: coords.to_vec(), resp: rtx })
                .map_err(|_| anyhow::anyhow!("pjrt evaluator thread gone"))?;
            rrx.recv()?
        }

        /// Evaluate MoE power-law scenarios (S ≤ 256 per call; padded).
        pub fn moe(
            &self,
            u: &[f32],
            alpha: &[f32],
            params: &[f32],
        ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
            let s = alpha.len();
            anyhow::ensure!(s <= MOE_SCENARIOS, "too many scenarios: {s}");
            anyhow::ensure!(
                u.len() == s * MOE_EXPERTS && params.len() == s * 3,
                "shape mismatch"
            );
            let (rtx, rrx) = mpsc::channel();
            self.tx
                .lock()
                .unwrap()
                .send(Job::Moe {
                    u: u.to_vec(),
                    alpha: alpha.to_vec(),
                    params: params.to_vec(),
                    resp: rtx,
                })
                .map_err(|_| anyhow::anyhow!("pjrt evaluator thread gone"))?;
            rrx.recv()?
        }
    }

    impl Drop for PjrtService {
        fn drop(&mut self) {
            let _ = self.tx.lock().unwrap().send(Job::Shutdown);
            if let Some(h) = self.handle.take() {
                let _ = h.join();
            }
        }
    }

    fn evaluator_thread(
        rx: mpsc::Receiver<Job>,
        ready: mpsc::Sender<anyhow::Result<()>>,
        interp_path: &Path,
        interp_small_path: &Path,
        moe_path: &Path,
        grids: Vec<f32>,
    ) {
        let init = (|| -> anyhow::Result<_> {
            let client = xla::PjRtClient::cpu()?;
            let load = |p: &Path| -> anyhow::Result<xla::PjRtLoadedExecutable> {
                let proto = xla::HloModuleProto::from_text_file(p)?;
                let comp = xla::XlaComputation::from_proto(&proto);
                Ok(client.compile(&comp)?)
            };
            let interp = load(interp_path)?;
            // Older artifact sets may lack the small variant; fall back.
            let interp_small = if interp_small_path.exists() {
                Some(load(interp_small_path)?)
            } else {
                None
            };
            let moe = load(moe_path)?;
            // The grid surface lives on-device for the whole session: one
            // host->device upload instead of one per execute (§Perf iter 2).
            let grids_buf = client.buffer_from_host_buffer::<f32>(
                &grids,
                &[
                    crate::perfdb::tables::NUM_TABLES,
                    crate::perfdb::tables::NX,
                    crate::perfdb::tables::NY,
                    crate::perfdb::tables::NZ,
                ],
                None,
            )?;
            Ok((client, interp, interp_small, moe, grids_buf))
        })();
        let (client, interp_exe, interp_small_exe, moe_exe, grids_buf) = match init {
            Ok(v) => {
                let _ = ready.send(Ok(()));
                v
            }
            Err(e) => {
                let _ = ready.send(Err(e));
                return;
            }
        };

        while let Ok(job) = rx.recv() {
            match job {
                Job::Shutdown => break,
                Job::Interp { tids, coords, resp } => {
                    let _ = resp.send(run_interp(
                        &client,
                        &interp_exe,
                        interp_small_exe.as_ref(),
                        &grids_buf,
                        &tids,
                        &coords,
                    ));
                }
                Job::Moe { u, alpha, params, resp } => {
                    let _ = resp.send(run_moe(&moe_exe, &u, &alpha, &params));
                }
            }
        }
    }

    fn run_interp(
        client: &xla::PjRtClient,
        exe: &xla::PjRtLoadedExecutable,
        exe_small: Option<&xla::PjRtLoadedExecutable>,
        grids: &xla::PjRtBuffer,
        tids: &[i32],
        coords: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        let mut out = Vec::with_capacity(tids.len());
        let mut chunk_start = 0usize;
        while chunk_start < tids.len() || (tids.is_empty() && chunk_start == 0) {
            let remaining = tids.len() - chunk_start;
            // Pick the variant: pay for 256 slots when ≤256 queries remain.
            let (the_exe, batch) = match exe_small {
                Some(s) if remaining <= QUERY_BATCH_SMALL => (s, QUERY_BATCH_SMALL),
                _ => (exe, QUERY_BATCH),
            };
            let end = (chunk_start + batch).min(tids.len());
            let n = end - chunk_start;
            let mut t = vec![0i32; batch];
            let mut c = vec![0f32; batch * 3];
            t[..n].copy_from_slice(&tids[chunk_start..end]);
            c[..n * 3].copy_from_slice(&coords[chunk_start * 3..end * 3]);
            let t_buf = client.buffer_from_host_buffer::<i32>(&t, &[batch], None)?;
            let c_buf = client.buffer_from_host_buffer::<f32>(&c, &[batch, 3], None)?;
            // Buffer-level execute: the grid surface is device-resident.
            let result = the_exe.execute_b::<&xla::PjRtBuffer>(&[grids, &t_buf, &c_buf])?[0][0]
                .to_literal_sync()?;
            let lat = result.to_tuple1()?;
            let v: Vec<f32> = lat.to_vec()?;
            out.extend_from_slice(&v[..n]);
            chunk_start = end;
            if n == 0 {
                break;
            }
        }
        Ok(out)
    }

    fn run_moe(
        exe: &xla::PjRtLoadedExecutable,
        u: &[f32],
        alpha: &[f32],
        params: &[f32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let s = alpha.len();
        let mut u_p = vec![0.5f32; MOE_SCENARIOS * MOE_EXPERTS];
        let mut a_p = vec![0.5f32; MOE_SCENARIOS];
        let mut p_p = vec![1.0f32; MOE_SCENARIOS * 3];
        u_p[..u.len()].copy_from_slice(u);
        a_p[..s].copy_from_slice(alpha);
        p_p[..params.len()].copy_from_slice(params);
        // Padding rows must stay numerically benign: x_max=2, total=1.
        for i in s..MOE_SCENARIOS {
            p_p[i * 3] = 1.0;
            p_p[i * 3 + 1] = 2.0;
            p_p[i * 3 + 2] = 1.0;
        }
        let u_lit =
            xla::Literal::vec1(&u_p).reshape(&[MOE_SCENARIOS as i64, MOE_EXPERTS as i64])?;
        let a_lit = xla::Literal::vec1(&a_p);
        let p_lit = xla::Literal::vec1(&p_p).reshape(&[MOE_SCENARIOS as i64, 3])?;
        let result =
            exe.execute::<xla::Literal>(&[u_lit, a_lit, p_lit])?[0][0].to_literal_sync()?;
        let (loads, imb) = result.to_tuple2()?;
        let loads_v: Vec<f32> = loads.to_vec()?;
        let imb_v: Vec<f32> = imb.to_vec()?;
        Ok((loads_v[..s * MOE_EXPERTS].to_vec(), imb_v[..s].to_vec()))
    }
}

#[cfg(feature = "xla")]
pub use pjrt_impl::PjrtService;

/// [`LatencyOracle`] over the PJRT-executed Pallas interpolation kernel:
/// the hot path the service uses. Ops map to queries exactly as the
/// native path does; unprofiled ops use the same SoL fallback.
#[cfg(feature = "xla")]
pub struct PjrtOracle<'a> {
    pub svc: &'a PjrtService,
    pub db: &'a PerfDatabase,
}

#[cfg(feature = "xla")]
impl LatencyOracle for PjrtOracle<'_> {
    fn op_latency_us(&self, op: &Op) -> f64 {
        match query_for(op) {
            Some(q) => {
                let lat = self
                    .svc
                    .interp(&[q.table as i32], &[q.fx as f32, q.fy as f32, q.fz as f32])
                    .expect("pjrt interp");
                lat[0] as f64 * q.scale
            }
            None => sol::latency_us(&self.db.cluster, op),
        }
    }

    fn latency_batch(&self, ops: &[Op]) -> Vec<f64> {
        // ONE batched PJRT execution for all profiled ops — the whole
        // point of the AOT kernel (step sweeps collapse to one call).
        let mut tids = Vec::with_capacity(ops.len());
        let mut coords = Vec::with_capacity(ops.len() * 3);
        let mut idx = Vec::with_capacity(ops.len());
        let mut scales = Vec::with_capacity(ops.len());
        let mut out = vec![0.0f64; ops.len()];
        for (i, op) in ops.iter().enumerate() {
            match query_for(op) {
                Some(q) => {
                    tids.push(q.table as i32);
                    coords.extend_from_slice(&[q.fx as f32, q.fy as f32, q.fz as f32]);
                    idx.push(i);
                    scales.push(q.scale);
                }
                None => out[i] = sol::latency_us(&self.db.cluster, op),
            }
        }
        if !tids.is_empty() {
            let lat = self.svc.interp(&tids, &coords).expect("pjrt interp");
            for (j, &i) in idx.iter().enumerate() {
                out[i] = lat[j] as f64 * scales[j];
            }
        }
        out
    }

}

#[cfg(all(test, not(feature = "xla")))]
mod stub_tests {
    use super::*;
    use crate::perfdb::tables::GRID_LEN;

    #[test]
    fn stub_start_reports_missing_feature() {
        let err = PjrtService::start(Path::new("artifacts"), vec![0f32; GRID_LEN]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("pjrt"), "unhelpful stub error: {msg}");
    }

    #[test]
    fn stub_start_still_validates_grid_shape() {
        let err = PjrtService::start(Path::new("artifacts"), vec![0f32; 3]).unwrap_err();
        assert!(err.to_string().contains("grid payload length"));
    }
}

//! MoE grouped-GEMM latency: weight-streaming bound at low token counts,
//! compute bound at high counts, with the hot-expert tail of §4.4.1
//! ("the tail latency caused by the most heavily loaded expert ...
//! determines overall throughput in practice").

use crate::frameworks::FrameworkProfile;
use crate::hardware::GpuSpec;
use crate::models::Dtype;

/// Per-expert kernel dispatch overhead, microseconds (grouped-GEMM
/// launch + routing bookkeeping).
const PER_EXPERT_US: f64 = 0.4;

/// Grouped GEMM over `experts` resident experts receiving `tokens`
/// routed tokens total, gated-FFN shapes (`inter`, `hidden`),
/// microseconds.
///
/// `imbalance` γ ≥ 1 is the hottest-participant load factor from the
/// power-law routing model: the kernel (or the EP group) finishes when
/// its most loaded member does, so compute time scales by γ.
pub fn grouped_gemm_us(
    gpu: &GpuSpec,
    fw: &FrameworkProfile,
    tokens: u64,
    experts: u64,
    inter: u64,
    hidden: u64,
    dtype: Dtype,
    imbalance: f64,
) -> f64 {
    let t = tokens.max(1) as f64;
    let e = experts.max(1) as f64;
    let gamma = imbalance.max(1.0);

    // Gated FFN per token: gate+up (2·inter×hidden) + down (inter×hidden).
    let flops_per_token = 2.0 * 3.0 * inter as f64 * hidden as f64;
    // Tail: finish time set by the hottest share of the work.
    let t_compute = t * flops_per_token * gamma
        / (gpu.tflops(dtype) * 1e12 * fw.moe_eff * small_batch_util(t, e))
        * 1e6;

    // Weight streaming: every expert with ≥1 token loads its 3 matrices.
    // Expected active experts under ~uniform token scatter. Streaming is
    // a plain sequential read — it does NOT pay the permute/ragged-tiling
    // penalty that caps the compute path (`fw.moe_eff`), which is why
    // decode (memory-bound) stays near peak while prefill (compute-bound)
    // runs at grouped-GEMM efficiency.
    const STREAM_EFF: f64 = 0.85;
    let active = e * (1.0 - (-t / e).exp());
    let w_bytes = active * 3.0 * inter as f64 * hidden as f64 * dtype.bytes();
    let t_mem = w_bytes / (gpu.mem_bw_gbs * 1e3 * STREAM_EFF);

    t_compute.max(t_mem) + e * PER_EXPERT_US + gpu.launch_us
}

/// MXU fill for grouped GEMM: tokens-per-expert rows per expert GEMM.
fn small_batch_util(tokens: f64, experts: f64) -> f64 {
    let rows = tokens / experts;
    (rows / 128.0).clamp(0.04, 1.0).powf(0.5)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frameworks::Framework;
    use crate::hardware::h100_sxm;

    fn fx() -> (GpuSpec, FrameworkProfile) {
        (h100_sxm(), Framework::TrtLlm.profile())
    }

    #[test]
    fn low_tokens_weight_bound() {
        let (g, f) = fx();
        // 16 tokens over 128 experts: latency ≈ active-expert weight load.
        let t = grouped_gemm_us(&g, &f, 16, 128, 1536, 4096, Dtype::Fp8, 1.0);
        let active = 128.0 * (1.0 - (-16.0f64 / 128.0).exp());
        let w = active * 3.0 * 1536.0 * 4096.0 * 1.0 / (g.mem_bw_gbs * 1e3 * f.moe_eff);
        assert!(t > w * 0.9 && t < w * 2.5, "t={t} w={w}");
    }

    #[test]
    fn high_tokens_compute_bound_and_linear() {
        let (g, f) = fx();
        let t1 = grouped_gemm_us(&g, &f, 65536, 16, 1536, 4096, Dtype::Fp8, 1.0);
        let t2 = grouped_gemm_us(&g, &f, 131072, 16, 1536, 4096, Dtype::Fp8, 1.0);
        let r = t2 / t1;
        assert!(r > 1.7 && r < 2.3, "got {r}");
    }

    #[test]
    fn imbalance_inflates_latency() {
        let (g, f) = fx();
        let bal = grouped_gemm_us(&g, &f, 32768, 16, 1536, 4096, Dtype::Fp8, 1.0);
        let hot = grouped_gemm_us(&g, &f, 32768, 16, 1536, 4096, Dtype::Fp8, 2.0);
        assert!(hot > bal * 1.5, "bal={bal} hot={hot}");
    }

    #[test]
    fn imbalance_below_one_clamped() {
        let (g, f) = fx();
        let a = grouped_gemm_us(&g, &f, 1024, 16, 1536, 4096, Dtype::Fp8, 0.5);
        let b = grouped_gemm_us(&g, &f, 1024, 16, 1536, 4096, Dtype::Fp8, 1.0);
        assert_eq!(a, b);
    }
}

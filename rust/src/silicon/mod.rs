//! Synthetic silicon: the ground-truth kernel-latency substrate.
//!
//! The paper builds its PerfDatabase by profiling real GPUs (~30 GPU-hours
//! per platform-framework pair, §4.4). This module is the substitution
//! (DESIGN.md): a parametric model of GPU kernel latency with the
//! nonlinearities that make naive roofline models diverge from production
//! — wave quantization, small-M tensor-core underutilization, kernel
//! launch overhead, hierarchical collective topology, MoE hot-expert
//! tails, and per-framework kernel efficiency / host overhead.
//!
//! Everything downstream treats this module as *opaque hardware*: the
//! PerfDatabase only observes it through noisy grid profiling
//! ([`crate::perfdb::builder`]), the measurement synthesizer samples it
//! through the same noise model to emit external measurement sets
//! ([`crate::perfdb::measure`] — the committed set under
//! `artifacts/measurements/` is a biased mirror of these kernels), and
//! the discrete-event simulator uses it directly (plus jitter) as the
//! stand-in for real engine runs.

pub mod attention;
pub mod comm;
pub mod gemm;
pub mod moe;

use crate::frameworks::FrameworkProfile;
use crate::hardware::ClusterSpec;
use crate::ops::Op;
use crate::util::rng::Rng;

/// Measurement-noise sigma (lognormal) applied when sampling latencies,
/// mirroring real profiling variance.
pub const MEASURE_SIGMA: f64 = 0.03;

/// The synthetic hardware+framework under test.
#[derive(Clone, Debug)]
pub struct Silicon {
    pub cluster: ClusterSpec,
    pub fw: FrameworkProfile,
}

impl Silicon {
    pub fn new(cluster: ClusterSpec, fw: FrameworkProfile) -> Self {
        Silicon { cluster, fw }
    }

    /// Deterministic (noise-free) latency of one op *instance*,
    /// microseconds. Multiply by `op.count()` for the full contribution.
    pub fn op_latency_us(&self, op: &Op) -> f64 {
        let gpu = &self.cluster.gpu;
        match *op {
            Op::Gemm { m, n, k, dtype, .. } => gemm::latency_us(gpu, &self.fw, m, n, k, dtype),
            Op::AttnPrefill { q_tokens, kv_len, heads, head_dim, causal_frac, .. } => {
                attention::prefill_us(gpu, &self.fw, q_tokens, kv_len, heads, head_dim, causal_frac)
            }
            Op::AttnDecode { batch, kv_len, heads, head_dim, kv_token_bytes, .. } => {
                attention::decode_us(gpu, &self.fw, batch, kv_len, heads, head_dim, kv_token_bytes)
            }
            Op::MoeGemm { tokens, experts, inter, hidden, dtype, imbalance, .. } => {
                moe::grouped_gemm_us(gpu, &self.fw, tokens, experts, inter, hidden, dtype, imbalance)
            }
            Op::AllReduce { bytes, gpus, span, rails, .. } => {
                comm::allreduce_placed_us(&self.cluster, bytes, gpus, span, rails)
            }
            Op::AllGather { bytes, gpus, span, rails, .. } => {
                comm::allgather_placed_us(&self.cluster, bytes, gpus, span, rails)
            }
            Op::AllToAll { bytes, gpus, span, rails, .. } => {
                comm::alltoall_placed_us(&self.cluster, bytes, gpus, span, rails)
            }
            Op::P2p { bytes, cross_node, .. } => comm::p2p_us(&self.cluster, bytes, cross_node),
            Op::Elementwise { bytes, .. } => {
                bytes / (gpu.mem_bw_gbs * 1e3) + gpu.launch_us
            }
        }
    }

    /// Per-instance latencies of a whole decomposed step in one call —
    /// the simulators price each `decompose` result as one batch
    /// through this (and the `LatencyOracle` impl forwards here).
    pub fn latency_batch(&self, ops: &[Op]) -> Vec<f64> {
        ops.iter().map(|o| self.op_latency_us(o)).collect()
    }

    /// Total latency of an op list (each op × its count), microseconds.
    pub fn step_latency_us(&self, ops: &[Op]) -> f64 {
        self.latency_batch(ops)
            .iter()
            .zip(ops)
            .map(|(lat, o)| lat * o.count() as f64)
            .sum()
    }

    /// One noisy "measurement" of an op instance, as a profiler would see.
    pub fn measure_us(&self, op: &Op, rng: &mut Rng) -> f64 {
        self.op_latency_us(op) * rng.noise(MEASURE_SIGMA)
    }

    /// Median of `k` noisy measurements (the profiling strategy the
    /// database builder uses).
    pub fn measure_median_us(&self, op: &Op, rng: &mut Rng, k: usize) -> f64 {
        let mut v: Vec<f64> = (0..k.max(1)).map(|_| self.measure_us(op, rng)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frameworks::Framework;
    use crate::hardware::h100_sxm;
    use crate::models::Dtype;

    fn sil() -> Silicon {
        Silicon::new(
            ClusterSpec::new(h100_sxm(), 8, 1),
            Framework::TrtLlm.profile(),
        )
    }

    #[test]
    fn latency_positive_and_monotone_in_m() {
        let s = sil();
        let mut last = 0.0;
        for m in [1u64, 64, 1024, 16384, 262144] {
            let t = s.op_latency_us(&Op::Gemm { m, n: 8192, k: 8192, dtype: Dtype::Fp16, count: 1 });
            assert!(t > 0.0 && t >= last, "m={m}: {t} < {last}");
            last = t;
        }
    }

    #[test]
    fn step_latency_sums_counts() {
        let s = sil();
        let op = Op::Elementwise { bytes: 1e6, count: 10 };
        let single = s.op_latency_us(&op);
        assert!((s.step_latency_us(&[op]) - 10.0 * single).abs() < 1e-9);
    }

    #[test]
    fn measurement_noise_is_small_and_unbiased() {
        let s = sil();
        let op = Op::Gemm { m: 4096, n: 4096, k: 4096, dtype: Dtype::Fp16, count: 1 };
        let truth = s.op_latency_us(&op);
        let mut rng = Rng::new(9);
        let n = 2000;
        let mean: f64 = (0..n).map(|_| s.measure_us(&op, &mut rng)).sum::<f64>() / n as f64;
        assert!((mean / truth - 1.0).abs() < 0.01, "mean {mean} vs truth {truth}");
    }

    #[test]
    fn median_of_measurements_stable() {
        let s = sil();
        let op = Op::Elementwise { bytes: 1e7, count: 1 };
        let truth = s.op_latency_us(&op);
        let mut rng = Rng::new(5);
        let med = s.measure_median_us(&op, &mut rng, 5);
        assert!((med / truth - 1.0).abs() < 0.08);
    }
}

//! GEMM latency model: roofline + wave quantization + small-M
//! tensor-core underutilization + launch overhead.
//!
//! These are exactly the effects that make "theoretical roofline models
//! often diverge from production performance" (paper §2.1) — they are the
//! reason AIConfigurator interpolates *measured* grids instead of
//! evaluating a formula, and the reason our fidelity experiments have a
//! non-trivial gap to close.

use crate::frameworks::FrameworkProfile;
use crate::hardware::GpuSpec;
use crate::models::Dtype;

/// Tensor-core tile geometry used for quantization effects.
const TILE_M: u64 = 128;
const TILE_N: u64 = 128;
/// Concurrent CTAs per SM for GEMM kernels.
const CTAS_PER_SM: u64 = 1;

/// Latency of a single `[m,k] x [k,n]` GEMM, microseconds.
pub fn latency_us(gpu: &GpuSpec, fw: &FrameworkProfile, m: u64, n: u64, k: u64, dtype: Dtype) -> f64 {
    let (m, n, k) = (m.max(1), n.max(1), k.max(1));
    let flops = 2.0 * m as f64 * n as f64 * k as f64;

    // -- Compute bound -----------------------------------------------------
    let peak = gpu.tflops(dtype) * 1e12; // FLOP/s
    let util = tensor_core_util(gpu, m, n);
    let t_compute = flops / (peak * fw.gemm_eff * util) * 1e6;

    // -- Memory bound ------------------------------------------------------
    // bytes / (BW GB/s) in µs = bytes / (BW * 1e9) * 1e6 = bytes / (BW * 1e3).
    let w_bytes = n as f64 * k as f64 * dtype.bytes();
    let act_bytes = (m * k + m * n) as f64 * 2.0;
    let t_mem = (w_bytes + act_bytes) / (gpu.mem_bw_gbs * 1e3) / fw.gemm_eff;

    t_compute.max(t_mem) + gpu.launch_us
}

/// Effective tensor-core utilization for an (m, n) problem:
/// wave quantization (last wave underfilled) × intra-tile fill on M.
fn tensor_core_util(gpu: &GpuSpec, m: u64, n: u64) -> f64 {
    let tiles_m = m.div_ceil(TILE_M);
    let tiles_n = n.div_ceil(TILE_N);
    let tiles = tiles_m * tiles_n;
    let slots = gpu.sm_count as u64 * CTAS_PER_SM;
    let waves = tiles.div_ceil(slots);
    // Fraction of the issued waves' slots actually used (last wave may be
    // nearly empty — the classic quantization cliff).
    let wave_util = tiles as f64 / (waves * slots) as f64;
    // Fill of the M dimension inside a tile (decode GEMMs have m << 128:
    // tensor cores stream the full K×N weights regardless → bandwidth
    // bound, but the compute path also can't saturate the MXU).
    let fill_m = (m as f64 / (tiles_m * TILE_M) as f64).clamp(0.05, 1.0);
    // Small-m problems additionally pay reduced occupancy.
    let occ = if m < 16 { 0.6 } else { 1.0 };
    (wave_util * (0.35 + 0.65 * fill_m) * occ).clamp(0.02, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frameworks::Framework;
    use crate::hardware::h100_sxm;

    fn fx() -> (GpuSpec, FrameworkProfile) {
        (h100_sxm(), Framework::TrtLlm.profile())
    }

    #[test]
    fn big_gemm_near_peak() {
        let (g, f) = fx();
        // 8k^3 fp16 GEMM: should land at 60-95% of peak.
        let t = latency_us(&g, &f, 8192, 8192, 8192, Dtype::Fp16);
        let achieved_tflops = 2.0 * 8192f64.powi(3) / (t * 1e-6) / 1e12;
        assert!(
            achieved_tflops > 0.6 * g.fp16_tflops && achieved_tflops < g.fp16_tflops,
            "achieved {achieved_tflops} TFLOPs"
        );
    }

    #[test]
    fn fp8_faster_than_fp16() {
        let (g, f) = fx();
        let t16 = latency_us(&g, &f, 4096, 8192, 8192, Dtype::Fp16);
        let t8 = latency_us(&g, &f, 4096, 8192, 8192, Dtype::Fp8);
        assert!(t8 < t16 * 0.75, "fp8 {t8} vs fp16 {t16}");
    }

    #[test]
    fn small_m_is_bandwidth_bound() {
        let (g, f) = fx();
        // m=8 decode GEMM: latency ≈ weight streaming time, not flops.
        let t = latency_us(&g, &f, 8, 8192, 8192, Dtype::Fp16);
        let w_time = 8192.0 * 8192.0 * 2.0 / (g.mem_bw_gbs * 1e3);
        assert!(t > w_time && t < w_time * 3.0 + g.launch_us * 2.0, "t={t} w={w_time}");
    }

    #[test]
    fn launch_overhead_floors_tiny_gemms() {
        let (g, f) = fx();
        let t = latency_us(&g, &f, 1, 64, 64, Dtype::Fp16);
        assert!(t >= g.launch_us);
        assert!(t < g.launch_us * 2.0);
    }

    #[test]
    fn wave_quantization_sawtooth_exists() {
        let (g, f) = fx();
        // Just past a wave boundary the latency jumps relative to flops.
        // Use a compute-bound shape: n=k=4096 → 32 column tiles; 132 SMs
        // fit 4 row tiles per wave (128 tiles). m=512 fills exactly one
        // wave; m=640 spills into a second, mostly-idle wave.
        let per_flop = |m: u64| {
            latency_us(&g, &f, m, 4096, 4096, Dtype::Fp16)
                / (2.0 * m as f64 * 4096.0 * 4096.0)
        };
        assert!(per_flop(640) > per_flop(512) * 1.2);
    }
}

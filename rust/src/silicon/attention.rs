//! Attention latency models: compute-bound fused prefill
//! (FlashAttention-class, paper §4.2 "prefill ... compute intensive")
//! and memory-bound batched decode (XQA/PagedAttention-class,
//! "decode ... memory intensive").

use crate::frameworks::FrameworkProfile;
use crate::hardware::GpuSpec;

/// Fused prefill attention for ONE request, microseconds.
///
/// FLOPs = 2 GEMMs (QKᵀ and PV) = 4 · heads · q · kv · head_dim, scaled
/// by the causal fraction (a causal kernel skips the upper triangle).
pub fn prefill_us(
    gpu: &GpuSpec,
    fw: &FrameworkProfile,
    q_tokens: u64,
    kv_len: u64,
    heads: u64,
    head_dim: u64,
    causal_frac: f64,
) -> f64 {
    let q = q_tokens.max(1) as f64;
    let kv = kv_len.max(1) as f64;
    let flops = 4.0 * heads as f64 * q * kv * head_dim as f64 * causal_frac;

    // Short sequences can't fill the MXU: efficiency ramps with kv.
    let seq_fill = (kv / 1024.0).clamp(0.15, 1.0);
    // Few heads (high TP) underfill the grid on small problems.
    let head_fill = (heads as f64 / 8.0).clamp(0.5, 1.0);
    let eff = fw.attn_prefill_eff * seq_fill.powf(0.35) * head_fill.powf(0.2);

    let t_compute = flops / (gpu.fp16_tflops * 1e12 * eff) * 1e6;

    // IO: Q/K/V/O streaming (FlashAttention never materializes q×kv).
    let io_bytes = (2 * q_tokens + 2 * kv_len) as f64 * heads as f64 * head_dim as f64 * 2.0;
    let t_mem = io_bytes / (gpu.mem_bw_gbs * 1e3);

    t_compute.max(t_mem) + gpu.launch_us
}

/// Batched decode attention, microseconds: `batch` one-token queries
/// each reading a `kv_len`-deep cache.
///
/// Dominated by KV reads: bytes = batch · kv_len · kv_token_bytes.
/// Small batches can't saturate HBM (few concurrent CTAs), which is why
/// real decode kernels show a bandwidth ramp — captured by `bw_fill`.
pub fn decode_us(
    gpu: &GpuSpec,
    fw: &FrameworkProfile,
    batch: u64,
    kv_len: u64,
    heads: u64,
    head_dim: u64,
    kv_token_bytes: f64,
) -> f64 {
    let b = batch.max(1) as f64;
    let kv = kv_len.max(1) as f64;

    let bytes = b * kv * kv_token_bytes;
    // Achievable bandwidth ramps with concurrency (batch × heads CTAs).
    let ctas = (b * heads as f64 / 8.0).max(1.0);
    let bw_fill = (ctas / gpu.sm_count as f64).clamp(0.25, 1.0);
    let t_mem = bytes / (gpu.mem_bw_gbs * 1e3 * fw.attn_decode_eff * bw_fill);

    // Compute side (matters for MLA where per-token math is heavy).
    let flops = 4.0 * b * heads as f64 * head_dim as f64 * kv;
    let t_compute = flops / (gpu.fp16_tflops * 1e12 * 0.25) * 1e6; // vector-ish kernel

    t_mem.max(t_compute) + gpu.launch_us
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frameworks::Framework;
    use crate::hardware::h100_sxm;

    fn fx() -> (GpuSpec, FrameworkProfile) {
        (h100_sxm(), Framework::TrtLlm.profile())
    }

    #[test]
    fn prefill_quadratic_in_seq() {
        let (g, f) = fx();
        let t1 = prefill_us(&g, &f, 1024, 1024, 32, 128, 0.5);
        let t4 = prefill_us(&g, &f, 4096, 4096, 32, 128, 0.5);
        let r = (t4 - g.launch_us) / (t1 - g.launch_us);
        assert!(r > 10.0 && r < 20.0, "expected ~16x, got {r}");
    }

    #[test]
    fn decode_linear_in_kv_at_saturation() {
        let (g, f) = fx();
        let t1 = decode_us(&g, &f, 64, 2048, 32, 128, 4096.0);
        let t2 = decode_us(&g, &f, 64, 4096, 32, 128, 4096.0);
        let r = (t2 - g.launch_us) / (t1 - g.launch_us);
        assert!(r > 1.8 && r < 2.2, "expected ~2x, got {r}");
    }

    #[test]
    fn decode_memory_bound_at_big_batch() {
        let (g, f) = fx();
        let kv_bytes = 4096.0;
        let t = decode_us(&g, &f, 128, 4096, 32, 128, kv_bytes);
        let ideal = 128.0 * 4096.0 * kv_bytes / (g.mem_bw_gbs * 1e3);
        assert!(t > ideal && t < ideal * 2.0, "t={t} ideal={ideal}");
    }

    #[test]
    fn small_batch_decode_underutilizes_bandwidth() {
        let (g, f) = fx();
        // Per-request cost should be higher at batch 1 than at batch 64.
        let per1 = decode_us(&g, &f, 1, 4096, 32, 128, 4096.0);
        let per64 = decode_us(&g, &f, 64, 4096, 32, 128, 4096.0) / 64.0;
        assert!(per1 > per64 * 1.5, "b1={per1} b64/64={per64}");
    }

    #[test]
    fn causal_halves_prefill_compute() {
        let (g, f) = fx();
        let full = prefill_us(&g, &f, 8192, 8192, 32, 128, 1.0);
        let causal = prefill_us(&g, &f, 8192, 8192, 32, 128, 0.5);
        let r = (full - g.launch_us) / (causal - g.launch_us);
        assert!(r > 1.8 && r < 2.2, "got {r}");
    }
}

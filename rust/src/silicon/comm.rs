//! Communication primitives (paper §4.4 "AllReduce, AllGather,
//! AllToAll, and point-to-point transfers across message sizes and GPU
//! counts").
//!
//! Since the topology subsystem landed, the cost models live in
//! [`crate::topology::collective`]: legacy (flat NVLink-vs-IB) fabrics
//! price through the seed's closed-form ring formulas bit-for-bit,
//! tiered fabrics through per-algorithm min-cost selection over the
//! placement's link path. This module keeps the seed's public entry
//! points (packed placement) and adds the `_placed` variants the op
//! pricing uses.

use crate::hardware::ClusterSpec;
use crate::topology::collective;

/// Protocol/algorithm efficiency of NCCL-class collectives vs raw link
/// BW (re-exported from the topology layer — one constant, two eras).
pub const COLL_EFF: f64 = collective::COLL_EFF;

/// Ring all-reduce of `bytes` (full tensor) across `gpus`,
/// microseconds, at the packed placement.
pub fn allreduce_us(c: &ClusterSpec, bytes: f64, gpus: u32) -> f64 {
    collective::allreduce_us(c, bytes, gpus, 1, 1)
}

/// All-gather where each GPU contributes `bytes` shard, microseconds.
pub fn allgather_us(c: &ClusterSpec, bytes: f64, gpus: u32) -> f64 {
    collective::allgather_us(c, bytes, gpus, 1, 1)
}

/// All-to-all of `bytes` sent per GPU (MoE dispatch/combine patterns,
/// DeepEP-style), microseconds.
pub fn alltoall_us(c: &ClusterSpec, bytes: f64, gpus: u32) -> f64 {
    collective::alltoall_us(c, bytes, gpus, 1, 1)
}

/// Placed all-reduce: the group spread over `span` NVLink domains with
/// `rails`-way striping (see [`crate::topology::Placement`]).
pub fn allreduce_placed_us(c: &ClusterSpec, bytes: f64, gpus: u32, span: u32, rails: u32) -> f64 {
    collective::allreduce_us(c, bytes, gpus, span, rails)
}

/// Placed all-gather.
pub fn allgather_placed_us(c: &ClusterSpec, bytes: f64, gpus: u32, span: u32, rails: u32) -> f64 {
    collective::allgather_us(c, bytes, gpus, span, rails)
}

/// Placed all-to-all.
pub fn alltoall_placed_us(c: &ClusterSpec, bytes: f64, gpus: u32, span: u32, rails: u32) -> f64 {
    collective::alltoall_us(c, bytes, gpus, span, rails)
}

/// Point-to-point transfer (PP boundary, disaggregated KV transfer).
pub fn p2p_us(c: &ClusterSpec, bytes: f64, cross_node: bool) -> f64 {
    collective::p2p_us(c, bytes, cross_node, 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::{h100_sxm, ClusterSpec};

    fn cluster(nodes: u32) -> ClusterSpec {
        ClusterSpec::new(h100_sxm(), 8, nodes)
    }

    #[test]
    fn single_gpu_is_free() {
        let c = cluster(1);
        assert_eq!(allreduce_us(&c, 1e6, 1), 0.0);
        assert_eq!(alltoall_us(&c, 1e6, 1), 0.0);
    }

    #[test]
    fn allreduce_scales_with_bytes() {
        let c = cluster(1);
        // Compare sizes where bandwidth dominates the latency floor.
        let t1 = allreduce_us(&c, 1e7, 8);
        let t2 = allreduce_us(&c, 1e9, 8);
        assert!(t2 > t1 * 20.0, "t1={t1} t2={t2}");
    }

    #[test]
    fn cross_node_much_slower() {
        let c = cluster(2);
        let intra = allreduce_us(&c, 1e8, 8);
        let inter = allreduce_us(&c, 1e8, 16);
        assert!(inter > intra * 3.0, "intra={intra} inter={inter}");
    }

    #[test]
    fn small_message_latency_floor() {
        let c = cluster(1);
        let t = allreduce_us(&c, 1024.0, 8);
        assert!(t >= 2.0 * 7.0 * c.fabric.intra_latency_us * 0.99);
    }

    #[test]
    fn p2p_link_selection() {
        let c = cluster(2);
        let nv = p2p_us(&c, 1e8, false);
        let ib = p2p_us(&c, 1e8, true);
        assert!(ib > nv * 5.0, "nv={nv} ib={ib}");
    }

    #[test]
    fn allgather_total_data_scales_with_g() {
        let c = cluster(1);
        let t2 = allgather_us(&c, 1e7, 2);
        let t8 = allgather_us(&c, 1e7, 8);
        assert!(t8 > t2 * 2.0);
    }

    #[test]
    fn placed_variants_match_packed_on_legacy_fabric() {
        // The legacy model ignores spans: every placement prices
        // identically (the seed behavior, bit-for-bit).
        let c = cluster(2);
        assert_eq!(allreduce_placed_us(&c, 1e8, 16, 2, 1), allreduce_us(&c, 1e8, 16));
        assert_eq!(alltoall_placed_us(&c, 1e7, 8, 2, 4), alltoall_us(&c, 1e7, 8));
        assert_eq!(allgather_placed_us(&c, 1e7, 16, 2, 4), allgather_us(&c, 1e7, 16));
    }
}

//! Communication primitives: ring all-reduce / all-gather, MoE
//! all-to-all and point-to-point, over a two-level NVLink+IB topology
//! (paper §4.4 "AllReduce, AllGather, AllToAll, and point-to-point
//! transfers across message sizes and GPU counts").

use crate::hardware::{ClusterSpec, LinkKind};

/// Protocol/algorithm efficiency of NCCL-class collectives vs raw link BW.
const COLL_EFF: f64 = 0.80;

fn per_gpu_bw_kbus(c: &ClusterSpec, gpus: u32) -> (f64, f64) {
    // Returns (bandwidth in bytes/us, base latency us).
    let link = c.link_for(gpus);
    let bw = c.p2p_bw_gbs(link) * 1e3 * COLL_EFF; // GB/s -> bytes/us
    (bw, c.link_latency_us(link))
}

/// Ring all-reduce of `bytes` (full tensor) across `gpus`, microseconds.
pub fn allreduce_us(c: &ClusterSpec, bytes: f64, gpus: u32) -> f64 {
    if gpus <= 1 {
        return 0.0;
    }
    let (bw, lat) = per_gpu_bw_kbus(c, gpus);
    let g = gpus as f64;
    // Ring: 2(g-1)/g of the data crosses each link; 2(g-1) latency hops.
    let t = 2.0 * (g - 1.0) / g * bytes / bw + 2.0 * (g - 1.0) * lat;
    // Hierarchical penalty when spanning nodes: the IB stage moves
    // bytes/node_count at far lower bandwidth — dominate via min BW
    // (already selected) plus an extra intra-node stage.
    if c.link_for(gpus) == LinkKind::InfiniBand {
        let intra = allreduce_us(c, bytes, c.gpus_per_node.min(gpus));
        t + 0.5 * intra
    } else {
        t
    }
}

/// All-gather where each GPU contributes `bytes` shard, microseconds.
pub fn allgather_us(c: &ClusterSpec, bytes: f64, gpus: u32) -> f64 {
    if gpus <= 1 {
        return 0.0;
    }
    let (bw, lat) = per_gpu_bw_kbus(c, gpus);
    let g = gpus as f64;
    (g - 1.0) / g * bytes * g / bw + (g - 1.0) * lat
}

/// All-to-all of `bytes` sent per GPU (MoE dispatch/combine patterns,
/// DeepEP-style), microseconds.
pub fn alltoall_us(c: &ClusterSpec, bytes: f64, gpus: u32) -> f64 {
    if gpus <= 1 {
        return 0.0;
    }
    let (bw, lat) = per_gpu_bw_kbus(c, gpus);
    let g = gpus as f64;
    (g - 1.0) / g * bytes / bw + lat * (g - 1.0).sqrt() * 2.0
}

/// Point-to-point transfer (PP boundary, disaggregated KV transfer).
pub fn p2p_us(c: &ClusterSpec, bytes: f64, cross_node: bool) -> f64 {
    let link = if cross_node { LinkKind::InfiniBand } else { LinkKind::NvLink };
    let bw = c.p2p_bw_gbs(link) * 1e3 * 0.9;
    c.link_latency_us(link) + bytes / bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::{h100_sxm, ClusterSpec};

    fn cluster(nodes: u32) -> ClusterSpec {
        ClusterSpec::new(h100_sxm(), 8, nodes)
    }

    #[test]
    fn single_gpu_is_free() {
        let c = cluster(1);
        assert_eq!(allreduce_us(&c, 1e6, 1), 0.0);
        assert_eq!(alltoall_us(&c, 1e6, 1), 0.0);
    }

    #[test]
    fn allreduce_scales_with_bytes() {
        let c = cluster(1);
        // Compare sizes where bandwidth dominates the latency floor.
        let t1 = allreduce_us(&c, 1e7, 8);
        let t2 = allreduce_us(&c, 1e9, 8);
        assert!(t2 > t1 * 20.0, "t1={t1} t2={t2}");
    }

    #[test]
    fn cross_node_much_slower() {
        let c = cluster(2);
        let intra = allreduce_us(&c, 1e8, 8);
        let inter = allreduce_us(&c, 1e8, 16);
        assert!(inter > intra * 3.0, "intra={intra} inter={inter}");
    }

    #[test]
    fn small_message_latency_floor() {
        let c = cluster(1);
        let t = allreduce_us(&c, 1024.0, 8);
        assert!(t >= 2.0 * 7.0 * c.nvlink_latency_us * 0.99);
    }

    #[test]
    fn p2p_link_selection() {
        let c = cluster(2);
        let nv = p2p_us(&c, 1e8, false);
        let ib = p2p_us(&c, 1e8, true);
        assert!(ib > nv * 5.0, "nv={nv} ib={ib}");
    }

    #[test]
    fn allgather_total_data_scales_with_g() {
        let c = cluster(1);
        let t2 = allgather_us(&c, 1e7, 2);
        let t8 = allgather_us(&c, 1e7, 8);
        assert!(t8 > t2 * 2.0);
    }
}

//! AIConfigurator reproduction: analytical configuration search for
//! multi-framework LLM serving (see README.md for the repo map).

// The codebase favours explicit index loops and inherent `to_string`
// helpers in its dependency-free JSON layer; keep clippy's default set
// quiet about those idioms so `-D warnings` stays meaningful for the
// rest.
#![allow(
    clippy::inherent_to_string,
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity
)]

pub mod config;
pub mod experiments;
pub mod fleetsim;
pub mod frameworks;
pub mod generator;
pub mod hardware;
pub mod metrics;
pub mod models;
pub mod ops;
pub mod pareto;
pub mod perfdb;
pub mod planner;
pub mod perfmodel;
pub mod runtime;
pub mod search;
pub mod service;
pub mod silicon;
pub mod simulator;
pub mod topology;
pub mod trace;
pub mod util;
pub mod workload;

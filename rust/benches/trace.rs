//! Bench: tracing overhead — the same pruned search clocked with no
//! recorder installed vs recorded end-to-end (spans on the grid build,
//! every pricing batch and the frontier merge, plus per-worker
//! lifetime spans). The acceptance bar is a <= 5% median regression
//! (`tests/artifacts.rs::bench_trace_keeps_its_contract`); tracing
//! *off* is pinned separately as bit-identical and a single
//! thread-local check per instrumentation point.
//!
//! Run: `cargo bench --bench trace` (or `make bench-trace`).
//! Writes the measured medians to ../BENCH_trace.json.

use aiconfigurator::config::WorkloadSpec;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::perfdb::{LatencyOracle, PerfDatabase};
use aiconfigurator::search::{RunOptions, SearchSpace, TaskRunner};
use aiconfigurator::silicon::Silicon;
use aiconfigurator::trace;
use aiconfigurator::util::bench::{bench, black_box};
use aiconfigurator::util::json::{self, Json};
use aiconfigurator::util::stats;

fn main() {
    let model_name = "qwen3-32b";
    let model = by_name(model_name).unwrap();
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let silicon = Silicon::new(cluster, Framework::TrtLlm.profile());
    let db = PerfDatabase::build(&silicon, &model, Dtype::Fp8, 0xA1C0);
    let space = SearchSpace::default_for(&model, Framework::TrtLlm);
    let wl = WorkloadSpec::new(model_name, 2048, 256, 1500.0, 20.0);
    let runner = TaskRunner::new(&model, &cluster, space, wl);
    let opts = RunOptions { prune: true };

    assert!(!trace::enabled(), "bench must start on an untraced thread");
    let off = bench(&format!("search-untraced/{model_name}"), 1, 5, || {
        black_box(runner.run_with(&db as &dyn LatencyOracle, &opts));
    });

    // Traced samples: each gets a fresh recorder so span buffers never
    // accumulate across iterations (matching one `--trace-out` run).
    let mut on_samples = Vec::new();
    let mut spans_recorded = 0usize;
    for _ in 0..5 {
        let rec = trace::Recorder::new();
        rec.install();
        let t = std::time::Instant::now();
        black_box(runner.run_with(&db as &dyn LatencyOracle, &opts));
        on_samples.push(t.elapsed().as_secs_f64() * 1e3);
        spans_recorded = rec.finish().len();
    }
    let on_ms = stats::median(&on_samples);
    let overhead = on_ms / off.median_ms().max(1e-9) - 1.0;
    println!(
        "search-traced/{model_name}: median {on_ms:.3} ms ({spans_recorded} spans; \
         {:+.2}% vs untraced {:.3} ms)",
        overhead * 100.0,
        off.median_ms()
    );

    // Record the run (cwd is rust/ under `cargo bench`).
    let mut o = Json::obj();
    o.set("bench", json::s("trace"))
        .set("model", json::s(model_name))
        .set("search_off_ms_median", json::num(off.median_ms()))
        .set("search_on_ms_median", json::num(on_ms))
        .set("overhead_frac", json::num(overhead))
        .set("spans_recorded", json::num(spans_recorded as f64));
    match std::fs::write("../BENCH_trace.json", o.to_string()) {
        Ok(()) => println!("    -> wrote ../BENCH_trace.json"),
        Err(e) => println!("    -> could not write ../BENCH_trace.json: {e}"),
    }
}

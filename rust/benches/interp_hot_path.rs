//! Bench: the interpolation hot path — native Rust trilinear vs the
//! AOT-compiled Pallas kernel through PJRT, across batch sizes; plus
//! the MoE power-law sampler (native vs kernel). This is the §Perf L3/L1
//! measurement recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo bench --bench interp_hot_path`

use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::perfdb::query::trilinear;
use aiconfigurator::perfdb::PerfDatabase;
use aiconfigurator::perfmodel::moe;
use aiconfigurator::runtime::{PjrtService, MOE_EXPERTS};
use aiconfigurator::silicon::Silicon;
use aiconfigurator::util::bench::{bench, black_box};
use aiconfigurator::util::rng::Rng;

fn main() {
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let silicon = Silicon::new(cluster, Framework::TrtLlm.profile());
    let model = by_name("qwen3-235b").unwrap();
    let db = PerfDatabase::build(&silicon, &model, Dtype::Fp8, 1);

    let mut rng = Rng::new(42);
    let n_max = 16384usize;
    let tids: Vec<i32> = (0..n_max).map(|_| rng.below(14) as i32).collect();
    let coords: Vec<f32> = (0..n_max * 3).map(|_| (rng.f64() * 15.0) as f32).collect();

    // --- Native path --------------------------------------------------
    for &n in &[1usize, 64, 1024, 8192] {
        let r = bench(&format!("native-interp/batch{n}"), 3, 30, || {
            let mut acc = 0.0f64;
            for i in 0..n {
                acc += trilinear(
                    db.grids(),
                    tids[i] as usize,
                    coords[i * 3] as f64,
                    coords[i * 3 + 1] as f64,
                    coords[i * 3 + 2] as f64,
                );
            }
            black_box(acc);
        });
        println!(
            "    -> {:.1} ns/query",
            r.median_ms() * 1e6 / n as f64
        );
    }

    // --- PJRT path ------------------------------------------------------
    let artifacts = std::path::Path::new("artifacts");
    if artifacts.join("interp.hlo.txt").exists() {
        let svc = PjrtService::start(artifacts, db.grids().to_vec()).unwrap();
        for &n in &[1usize, 64, 1024, 8192, 16384] {
            let r = bench(&format!("pjrt-interp/batch{n}"), 2, 15, || {
                black_box(svc.interp(&tids[..n], &coords[..n * 3]).unwrap());
            });
            println!(
                "    -> {:.1} ns/query (incl. channel + padding to 8192)",
                r.median_ms() * 1e6 / n as f64
            );
        }
        // MoE kernel.
        let s = 256usize;
        let u: Vec<f32> = (0..s * MOE_EXPERTS).map(|_| rng.f64_open() as f32).collect();
        let alpha: Vec<f32> = (0..s).map(|i| 0.1 + (i as f32) * 0.005).collect();
        let params: Vec<f32> = (0..s).flat_map(|_| [1.0f32, 100.0, 8192.0]).collect();
        bench("pjrt-moe-powerlaw/s256", 2, 15, || {
            black_box(svc.moe(&u, &alpha, &params).unwrap());
        });
    } else {
        println!("(artifacts/ missing — skipping PJRT benches; run `make artifacts`)");
    }

    // --- Native MoE sampler ----------------------------------------------
    bench("native-moe-gamma/e128-ep8", 3, 30, || {
        black_box(moe::ep_imbalance(128, 1.2, 8, 7, 16));
    });
}

//! Bench: fleet replay (`validate`) — plan once, then measure the
//! discrete-event replay of the plan's own trace through the fleet,
//! benign (faithful-execution) vs injected (lag + failures). The
//! replay is the expensive half of `aiconfigurator validate`; the plan
//! itself is covered by benches/planner.rs.
//!
//! Run: `cargo bench --bench validate` (or `make bench-validate`).
//! Writes the measured medians to ../BENCH_validate.json.

use aiconfigurator::config::WorkloadSpec;
use aiconfigurator::fleetsim::{self, FleetConfig, FleetLeg};
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::by_name;
use aiconfigurator::perfdb::{LatencyOracle, PerfDatabase};
use aiconfigurator::planner::{self, PlanSpec, TrafficModel};
use aiconfigurator::silicon::Silicon;
use aiconfigurator::util::bench::{bench, black_box};
use aiconfigurator::util::json::{self, Json};

fn main() {
    let model_name = "llama3.1-8b";
    let model = by_name(model_name).unwrap();
    let framework = Framework::TrtLlm;
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let sil = Silicon::new(cluster, framework.profile());
    let db = PerfDatabase::build(&sil, &model, cluster.gpu.preferred_kv_dtype(), 0xA1C0);
    let fleet: Vec<(ClusterSpec, &dyn LatencyOracle)> = vec![(cluster, &db)];

    // A short diurnal horizon: 6 windows of 72 s at 1-10 QPS keeps the
    // trace in the low thousands of requests.
    let wl = WorkloadSpec::new(model_name, 512, 64, 2000.0, 10.0);
    let windows = 6usize;
    let window_h = 0.02;
    let spec = PlanSpec::new(
        wl.clone(),
        TrafficModel::Diurnal { peak_qps: 10.0, trough_qps: 1.0, period_h: windows as f64 * window_h },
        windows,
        window_h,
    );
    let plan = planner::plan(&model, framework, &spec, &fleet).unwrap();
    let trace = spec.traffic.trace(windows, window_h, &wl, 0.1, 0xD15C);
    let legs = [FleetLeg { name: cluster.gpu.name.to_string(), cluster, silicon: &sil }];

    let benign_cfg = FleetConfig::default();
    let benign = bench(
        &format!("validate-benign-{}req-{windows}w/{model_name}", trace.len()),
        1,
        5,
        || {
            black_box(
                fleetsim::replay(&model, &spec, &plan, &legs, &trace, &benign_cfg).unwrap(),
            );
        },
    );

    let mut injected_cfg = benign_cfg;
    injected_cfg.scale_lag_s = 60.0;
    injected_cfg.failure_rate_per_replica_h = 2.0;
    injected_cfg.restart_s = 60.0;
    let injected = bench(
        &format!("validate-injected-{}req-{windows}w/{model_name}", trace.len()),
        1,
        5,
        || {
            black_box(
                fleetsim::replay(&model, &spec, &plan, &legs, &trace, &injected_cfg).unwrap(),
            );
        },
    );

    let rep = fleetsim::replay(&model, &spec, &plan, &legs, &trace, &benign_cfg).unwrap();
    let rep_inj = fleetsim::replay(&model, &spec, &plan, &legs, &trace, &injected_cfg).unwrap();
    println!(
        "    -> benign: promised {:.4} achieved {:.4} gap {:+.4} | injected: achieved {:.4} \
         ({} failures)",
        rep.promised_attainment,
        rep.achieved_attainment,
        rep.optimism_gap,
        rep_inj.achieved_attainment,
        rep_inj.failures,
    );
    println!(
        "    -> replay rate: {:.0} trace-requests/s benign, {:.0} injected",
        trace.len() as f64 / (benign.median_ms() / 1e3),
        trace.len() as f64 / (injected.median_ms() / 1e3),
    );

    // Record the run (cwd is rust/ under `cargo bench`).
    let mut o = Json::obj();
    o.set("bench", json::s("validate"))
        .set("model", json::s(model_name))
        .set("windows", json::num(windows as f64))
        .set("trace_requests", json::num(trace.len() as f64))
        .set("replay_benign_ms_median", json::num(benign.median_ms()))
        .set("replay_injected_ms_median", json::num(injected.median_ms()))
        .set("benign_optimism_gap", json::num(rep.optimism_gap))
        .set("injected_achieved_attainment", json::num(rep_inj.achieved_attainment))
        .set("injected_failures", json::num(rep_inj.failures as f64));
    match std::fs::write("../BENCH_validate.json", o.to_string()) {
        Ok(()) => println!("    -> wrote ../BENCH_validate.json"),
        Err(e) => println!("    -> could not write ../BENCH_validate.json: {e}"),
    }
}

//! Calibration-pipeline benches: fit cost, composition cost, and the
//! query-time overhead of the three-tier calibrated lookup vs. the
//! plain analytic interpolation (the tier chain adds a nearest-cell
//! probe + hash lookup + atomic bump per query — this bench pins that
//! it stays in the same order of magnitude).
//!
//! Run: `cargo bench --bench calibration`

use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::ops::Op;
use aiconfigurator::perfdb::{calibrate, measure, CalibratedDb, LatencyOracle, PerfDatabase};
use aiconfigurator::silicon::Silicon;
use aiconfigurator::util::bench::{bench, black_box};

fn main() {
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
    let model = by_name("qwen3-32b").unwrap();
    let db = PerfDatabase::build(&sil, &model, Dtype::Fp8, 0xA1C0);
    let sets = measure::synthesize(&sil, &model, Dtype::Fp8, 7, 48);

    println!("== calibration pipeline ==");
    bench("fit 14 tables x 48 points", 1, 10, || {
        black_box(calibrate::fit(&db, &sets).unwrap());
    });

    let art = calibrate::fit(&db, &sets).unwrap();
    bench("compose artifact over database", 1, 10, || {
        black_box(CalibratedDb::compose(db.clone(), &art).unwrap());
    });

    // Query overhead: a mixed op batch through both oracles.
    let cal = CalibratedDb::compose(db.clone(), &art).unwrap();
    let ops: Vec<Op> = (0..512)
        .map(|i| {
            let m = 1 + (i as u64 * 37) % 8192;
            Op::Gemm { m, n: 5120, k: 5120, dtype: Dtype::Fp8, count: 1 }
        })
        .collect();
    bench("512 queries, analytic interp", 2, 20, || {
        let mut acc = 0.0;
        for op in &ops {
            acc += db.op_latency_us(op);
        }
        black_box(acc);
    });
    bench("512 queries, calibrated 3-tier chain", 2, 20, || {
        let mut acc = 0.0;
        for op in &ops {
            acc += cal.op_latency_us(op);
        }
        black_box(acc);
    });
    let t = cal.tier_counts();
    println!(
        "tier mix over the bench: {} measured / {} calibrated / {} analytic / {} sol",
        t.measured, t.calibrated, t.analytic, t.sol
    );
}

//! Bench: Table 1 — configuration-search efficiency. Times the full
//! paper-scale sweep per model and prints the Table 1 rows plus
//! criterion-style timings for the search core, comparing the
//! work-stealing job-queue engine (`TaskRunner::run`) against the seed's
//! static-chunk implementation (`TaskRunner::run_baseline`) on the same
//! space — the wall-clock delta recorded in EXPERIMENTS.md.
//!
//! Run: `cargo bench --bench table1_search`

use aiconfigurator::config::WorkloadSpec;
use aiconfigurator::experiments::table1_efficiency;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::perfdb::PerfDatabase;
use aiconfigurator::search::{SearchSpace, TaskRunner};
use aiconfigurator::silicon::Silicon;
use aiconfigurator::util::bench::{bench, black_box, once};

fn main() {
    println!("--- Table 1 (paper-scale sweep) ---");
    let rep = table1_efficiency::run(false);
    println!("{}", rep.render());

    println!("--- search-core timings (seed baseline vs work-stealing pool) ---");
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    for name in ["llama3.1-8b", "qwen3-32b", "qwen3-235b"] {
        let model = by_name(name).unwrap();
        let silicon = Silicon::new(cluster, Framework::TrtLlm.profile());
        let db = once(&format!("build-db/{name}"), || {
            black_box(PerfDatabase::build(&silicon, &model, Dtype::Fp8, 1));
        });
        let _ = db;
        let dbv = PerfDatabase::build(&silicon, &model, Dtype::Fp8, 1);
        let wl = WorkloadSpec::new(name, 2048, 256, f64::INFINITY, 0.0);
        let space = SearchSpace::default_for(&model, Framework::TrtLlm);

        let seed = bench(&format!("search-seed-baseline/{name}"), 1, 10, || {
            let runner = TaskRunner::new(&model, &cluster, space.clone(), wl.clone());
            black_box(runner.run_baseline(&dbv));
        });
        let pooled = bench(&format!("search-sweep/{name}"), 1, 10, || {
            let runner = TaskRunner::new(&model, &cluster, space.clone(), wl.clone());
            black_box(runner.run(&dbv));
        });
        let pruned = bench(&format!("search-sweep-pruned/{name}"), 1, 10, || {
            let runner = TaskRunner::new(&model, &cluster, space.clone(), wl.clone());
            black_box(runner.run_pruned(&dbv));
        });
        println!(
            "    -> pool vs seed: {:.2}x  | pool+prune vs seed: {:.2}x",
            seed.median_ms() / pooled.median_ms(),
            seed.median_ms() / pruned.median_ms()
        );
    }
}

//! Bench: differential re-planning — a full from-scratch re-search of
//! the patched inputs vs the incremental `planner::replan` across delta
//! kinds (window demand edit, GPU reprice, added fleet leg). The first
//! two patch the retained frontier without any oracle work; the add-leg
//! delta re-sweeps exactly one leg — the bench records how much of the
//! full sweep each kind saves.
//!
//! Run: `cargo bench --bench replan` (or `make bench-replan`).
//! Writes the measured medians to ../BENCH_replan.json.

use aiconfigurator::config::WorkloadSpec;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{a100_sxm, h100_sxm, h200_sxm, ClusterSpec};
use aiconfigurator::models::by_name;
use aiconfigurator::perfdb::{LatencyOracle, MemoOracle};
use aiconfigurator::planner::{self, PlanSpec, TrafficModel};
use aiconfigurator::search::SearchDelta;
use aiconfigurator::silicon::Silicon;
use aiconfigurator::util::bench::{bench, black_box};
use aiconfigurator::util::json::{self, Json};
use aiconfigurator::util::stats;

fn main() {
    let model_name = "llama3.1-8b";
    let model = by_name(model_name).unwrap();
    let framework = Framework::TrtLlm;
    let wl = WorkloadSpec::new(model_name, 1024, 128, 2000.0, 10.0);
    let windows = 24usize;
    let spec = PlanSpec::new(
        wl.clone(),
        TrafficModel::Diurnal { peak_qps: 80.0, trough_qps: 4.0, period_h: 24.0 },
        windows,
        1.0,
    );
    let legs = [ClusterSpec::new(h100_sxm(), 8, 1), ClusterSpec::new(a100_sxm(), 8, 1)];
    let sils: Vec<Silicon> =
        legs.iter().map(|c| Silicon::new(*c, framework.profile())).collect();
    let h200 = ClusterSpec::new(h200_sxm(), 8, 1);
    let h200_sil = Silicon::new(h200, framework.profile());

    let window_delta = SearchDelta {
        window_edits: vec![(2, 140.0), (9, 15.0), (17, 55.0)],
        ..SearchDelta::default()
    };
    let reprice_delta = SearchDelta {
        reprice: vec![("h100".to_string(), 1.49)],
        ..SearchDelta::default()
    };
    let addleg_delta =
        SearchDelta { add_legs: vec!["h200".to_string()], ..SearchDelta::default() };

    // Baseline arena once, for the sweep-size denominator.
    let memos: Vec<MemoOracle<'_>> =
        sils.iter().map(|s| MemoOracle::new(s as &dyn LatencyOracle)).collect();
    let fleet: Vec<(ClusterSpec, &MemoOracle<'_>)> =
        legs.iter().zip(&memos).map(|(c, m)| (*c, m)).collect();
    let (_, arena0) = planner::plan_arena(&model, framework, &spec, &fleet).unwrap();
    let baseline_priced = arena0.baseline_priced_configs();

    // Full from-scratch re-search of the window-edited inputs: fresh
    // memos each iteration, exactly what a cold `plan` pays.
    let mut full_spec = spec.clone();
    full_spec.demand_override = window_delta.window_edits.clone();
    let full = bench(&format!("replan-full-resweep-{windows}w/{model_name}"), 1, 5, || {
        let cold: Vec<(ClusterSpec, &dyn LatencyOracle)> =
            legs.iter().zip(&sils).map(|(c, s)| (*c, s as &dyn LatencyOracle)).collect();
        black_box(planner::plan(&model, framework, &full_spec, &cold).unwrap());
    });

    // Incremental replans. Window edits and reprices are idempotent, so
    // one retained arena serves every sample; the add-leg delta grows
    // the arena, so each sample rebuilds its arena untimed and only the
    // `replan` call is clocked.
    let (baseline, mut arena) = planner::plan_arena(&model, framework, &spec, &fleet).unwrap();
    let win = bench(&format!("replan-window-edit-{windows}w/{model_name}"), 1, 5, || {
        black_box(
            planner::replan(&model, framework, &mut arena, &baseline, &window_delta, &[])
                .unwrap(),
        );
    });
    let rep = bench(&format!("replan-reprice-{windows}w/{model_name}"), 1, 5, || {
        black_box(
            planner::replan(&model, framework, &mut arena, &baseline, &reprice_delta, &[])
                .unwrap(),
        );
    });

    let mut addleg_samples = Vec::new();
    let mut addleg_repriced = 0usize;
    for _ in 0..5 {
        let (base, mut arena) = planner::plan_arena(&model, framework, &spec, &fleet).unwrap();
        let memo = MemoOracle::new(&h200_sil as &dyn LatencyOracle);
        let swept = [(h200, &memo)];
        let t = std::time::Instant::now();
        let r = planner::replan(&model, framework, &mut arena, &base, &addleg_delta, &swept)
            .unwrap();
        addleg_samples.push(t.elapsed().as_secs_f64() * 1e3);
        addleg_repriced = r.repriced_configs;
    }
    let addleg_ms = stats::median(&addleg_samples);
    println!(
        "replan-addleg-{windows}w/{model_name}: median {addleg_ms:.3} ms \
         ({addleg_repriced} configs re-priced)"
    );
    println!(
        "    -> full re-search prices {baseline_priced} configs in {:.1} ms; window-edit \
         replan {:.3} ms ({:.0}x), add-leg replan {:.1} ms pricing {addleg_repriced}",
        full.median_ms(),
        win.median_ms(),
        full.median_ms() / win.median_ms().max(1e-9),
        addleg_ms,
    );

    // Record the run (cwd is rust/ under `cargo bench`).
    let mut o = Json::obj();
    o.set("bench", json::s("replan"))
        .set("model", json::s(model_name))
        .set("windows", json::num(windows as f64))
        .set("baseline_priced_configs", json::num(baseline_priced as f64))
        .set("full_resweep_ms_median", json::num(full.median_ms()))
        .set("replan_window_ms_median", json::num(win.median_ms()))
        .set("replan_reprice_ms_median", json::num(rep.median_ms()))
        .set("replan_addleg_ms_median", json::num(addleg_ms))
        .set("addleg_repriced_configs", json::num(addleg_repriced as f64))
        .set("window_speedup", json::num(full.median_ms() / win.median_ms().max(1e-9)))
        .set("addleg_speedup", json::num(full.median_ms() / addleg_ms.max(1e-9)));
    match std::fs::write("../BENCH_replan.json", o.to_string()) {
        Ok(()) => println!("    -> wrote ../BENCH_replan.json"),
        Err(e) => println!("    -> could not write ../BENCH_replan.json: {e}"),
    }
}

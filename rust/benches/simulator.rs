//! Bench: ground-truth simulator step rate — aggregated continuous
//! batching and disaggregated pools. The simulator must stay fast enough
//! to serve as the "GPU benchmark" stand-in for paper-scale fidelity
//! sweeps (≥1000 configs).
//!
//! Run: `cargo bench --bench simulator`

use aiconfigurator::config::{EngineConfig, ParallelSpec, RuntimeFlags};
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::silicon::Silicon;
use aiconfigurator::simulator::{aggregated::AggregatedSim, disagg::DisaggSim, SimConfig};
use aiconfigurator::util::bench::{bench, black_box};
use aiconfigurator::workload::closed_loop;

fn eng(fw: Framework, tp: u32, batch: u32) -> EngineConfig {
    EngineConfig {
        framework: fw,
        parallel: ParallelSpec::tp(tp),
        batch,
        weight_dtype: Dtype::Fp8,
        kv_dtype: Dtype::Fp8,
        flags: RuntimeFlags::defaults_for(fw),
        placement: aiconfigurator::topology::Placement::packed(),
    }
}

fn main() {
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let silicon = Silicon::new(cluster, Framework::TrtLlm.profile());

    for model_name in ["qwen3-32b", "qwen3-235b"] {
        let model = by_name(model_name).unwrap();
        let e = eng(Framework::TrtLlm, 4, 32);
        let trace = closed_loop(64, 2048, 128);
        let mut iters = 0u64;
        let r = bench(&format!("sim-aggregated/{model_name}-b32"), 1, 10, || {
            let sim =
                AggregatedSim::new(&silicon, &model, &cluster, e, SimConfig::default());
            let res = sim.run(&trace);
            iters = res.iterations;
            black_box(res);
        });
        println!(
            "    -> {iters} iterations/run, {:.1} µs/iteration",
            r.median_ms() * 1e3 / iters as f64
        );
    }

    let model = by_name("qwen3-32b").unwrap();
    let trace = closed_loop(64, 2048, 128);
    let mut iters = 0u64;
    let r = bench("sim-disaggregated/qwen3-32b-4P2D", 1, 10, || {
        let sim = DisaggSim::new(
            &silicon,
            &model,
            &cluster,
            eng(Framework::TrtLlm, 1, 2),
            eng(Framework::TrtLlm, 2, 32),
            4,
            2,
            SimConfig::default(),
        );
        let res = sim.run(&trace);
        iters = res.iterations;
        black_box(res);
    });
    println!(
        "    -> {iters} iterations/run, {:.1} µs/iteration",
        r.median_ms() * 1e3 / iters as f64
    );
}

//! Closed-loop load bench for the L3 service pipeline: hundreds of
//! in-process clients (no sockets — the TCP layer is a thin line codec)
//! firing a mixed search/sweep/plan traffic pattern with repeated
//! request keys across two warm contexts, so coalescing and the shared
//! LRU cache both engage. Reports client-side latency quantiles,
//! sustained throughput, and the pipeline's own coalesce/cache rates.
//!
//! Writes the measured numbers to ../BENCH_service.json.
//!
//! Run: `cargo bench --bench service` (or `make bench-service`).

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use aiconfigurator::config::WorkloadSpec;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::service::{make_request, Pipeline, State};
use aiconfigurator::util::json::{self, Json};
use aiconfigurator::util::stats;

/// A v1 search request (agg-only so the bench times the pipeline, not
/// search breadth) against one of the two warm contexts.
fn search_req(isl: u32, gpn: u32, id: u64) -> Json {
    let wl = WorkloadSpec::new("llama3.1-8b", isl, 64, 2000.0, 5.0);
    let mut req = make_request(&wl, "h100", gpn, 1, Framework::TrtLlm, id);
    req.set("modes", Json::Arr(vec![json::s("agg")]));
    req
}

/// A two-scenario sweep on the gpn=8 context.
fn sweep_req(id: u64) -> Json {
    let mut req = Json::obj();
    req.set(
        "workloads",
        Json::Arr(vec![
            WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0).to_json(),
            WorkloadSpec::new("llama3.1-8b", 512, 64, 3000.0, 5.0).to_json(),
        ]),
    )
    .set("gpu", json::s("h100"))
    .set("gpus_per_node", json::num(8.0))
    .set("num_nodes", json::num(1.0))
    .set("framework", json::s("trtllm"))
    .set("modes", Json::Arr(vec![json::s("agg")]))
    .set("id", json::num(id as f64));
    req
}

/// A small capacity plan over the gpn=8 context (identical across
/// clients, so concurrent plans coalesce like searches do).
fn plan_req(id: u64) -> Json {
    let mut traffic = Json::obj();
    traffic
        .set("kind", json::s("diurnal"))
        .set("peak_qps", json::num(80.0))
        .set("trough_qps", json::num(4.0))
        .set("period_h", json::num(24.0));
    let mut plan = Json::obj();
    plan.set(
        "workload",
        WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0).to_json(),
    )
    .set("traffic", traffic)
    .set("windows", json::num(4.0))
    .set("window_hours", json::num(6.0))
    .set("fleet", Json::Arr(vec![json::s("h100")]));
    let mut req = Json::obj();
    req.set("plan", plan)
        .set("gpus_per_node", json::num(8.0))
        .set("num_nodes", json::num(1.0))
        .set("framework", json::s("trtllm"))
        .set("id", json::num(id as f64));
    req
}

fn main() {
    // Big queue + a real worker pool: the bench must measure pipeline
    // behaviour under saturation, not admission refusals.
    let clients = 256usize;
    let per_client = 4usize;
    let pipeline = Pipeline::new(Arc::new(State::new(0xBE7C)), 8, 4096);

    // Build both contexts outside the timed window (the cold DB build is
    // measured by the perfdb benches, not this one).
    for gpn in [8u32, 4] {
        let warm = pipeline.handle(&search_req(1024, gpn, 0));
        assert_eq!(warm.req_str("status").unwrap(), "ok", "{}", warm.to_string());
    }

    println!("service closed loop: {clients} clients x {per_client} requests, mixed ops");
    let lat_ms: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(clients * per_client));
    let errors_seen = std::sync::atomic::AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|sc| {
        for c in 0..clients {
            let (pipeline, lat_ms, errors_seen) = (&pipeline, &lat_ms, &errors_seen);
            sc.spawn(move || {
                let mut mine = Vec::with_capacity(per_client);
                for i in 0..per_client {
                    let r = c * per_client + i;
                    // ~6% plans, ~19% sweeps, the rest searches drawn
                    // from 4 repeated shapes across 2 contexts.
                    let req = if r % 16 == 0 {
                        plan_req(r as u64)
                    } else if r % 16 == 5 || r % 16 == 10 || r % 16 == 15 {
                        sweep_req(r as u64)
                    } else {
                        let isl = [512u32, 1024, 2048, 4096][r % 4];
                        let gpn = if r % 2 == 0 { 8 } else { 4 };
                        search_req(isl, gpn, r as u64)
                    };
                    let t = Instant::now();
                    let resp = pipeline.handle(&req);
                    mine.push(t.elapsed().as_secs_f64() * 1e3);
                    if resp.req_str("status").map(|s| s != "ok").unwrap_or(true) {
                        errors_seen.fetch_add(1, Ordering::Relaxed);
                    }
                }
                lat_ms.lock().unwrap().extend(mine);
            });
        }
    });
    let elapsed_s = t0.elapsed().as_secs_f64();
    let lat = lat_ms.into_inner().unwrap();
    let total = lat.len();
    assert_eq!(total, clients * per_client);
    assert_eq!(errors_seen.load(Ordering::Relaxed), 0, "load mix must answer clean");

    let st = pipeline.state();
    let p50 = stats::percentile(&lat, 50.0);
    let p99 = stats::percentile(&lat, 99.0);
    let throughput = total as f64 / elapsed_s;
    let coalesce_rate = st.stats.coalesce_rate();
    let gauges = st.cache().gauges();
    let cache_hit_rate = gauges.hit_rate();
    let shed = st.stats.shed.load(Ordering::Relaxed);
    let errors = st.stats.errors.load(Ordering::Relaxed);
    println!(
        "    -> {total} requests in {elapsed_s:.2}s ({throughput:.1} req/s), \
         p50 {p50:.2} ms  p99 {p99:.2} ms"
    );
    println!(
        "    -> coalesce rate {:.1}%  cache hit rate {:.1}%  shed {shed}  errors {errors}",
        coalesce_rate * 100.0,
        cache_hit_rate * 100.0
    );
    assert_eq!(shed, 0, "queue_limit=4096 must admit the whole mix");
    assert!(
        coalesce_rate > 0.0,
        "repeated request shapes under concurrency must coalesce"
    );
    assert!(cache_hit_rate > 0.5, "two contexts, {total} requests: almost all warm");

    // Record the run (cwd is rust/ under `cargo bench`).
    let mut o = Json::obj();
    o.set("bench", json::s("service"))
        .set("clients", json::num(clients as f64))
        .set("requests_total", json::num(total as f64))
        .set("elapsed_s", json::num(elapsed_s))
        .set("throughput_rps", json::num(throughput))
        .set("p50_ms", json::num(p50))
        .set("p99_ms", json::num(p99))
        .set("coalesce_rate", json::num(coalesce_rate))
        .set("cache_hit_rate", json::num(cache_hit_rate))
        .set("shed_total", json::num(shed as f64))
        .set("errors", json::num(errors as f64));
    match std::fs::write("../BENCH_service.json", o.to_string()) {
        Ok(()) => println!("    -> wrote ../BENCH_service.json"),
        Err(e) => println!("    -> could not write ../BENCH_service.json: {e}"),
    }
}

//! Bench: topology subsystem — placement enumeration over a parallel
//! shape grid, and the structural-grid build with the placement axis on
//! (tiered 2-node fabric) vs off (legacy), which bounds the search-side
//! cost of pricing placements.
//!
//! Run: `cargo bench --bench topology` (or `make bench-topo`).
//! Writes the measured medians to ../BENCH_topology.json.

use aiconfigurator::config::ParallelSpec;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::by_name;
use aiconfigurator::search::SearchSpace;
use aiconfigurator::silicon::comm;
use aiconfigurator::topology::{fabric, placement};
use aiconfigurator::util::bench::{bench, bench_items, black_box};
use aiconfigurator::util::json::{self, Json};

fn shape_grid() -> Vec<ParallelSpec> {
    let mut shapes = Vec::new();
    for tp in [1u32, 2, 4, 8, 16] {
        for pp in [1u32, 2, 4] {
            for ep in [1u32, 4, 8] {
                if ep <= tp {
                    shapes.push(ParallelSpec { tp, pp, ep, dp: 1 });
                }
            }
        }
    }
    shapes
}

fn main() {
    let shapes = shape_grid();
    let fabrics = fabric::all();
    let clusters: Vec<ClusterSpec> = fabrics
        .iter()
        .map(|f| ClusterSpec::with_fabric(h100_sxm(), 8, 4, *f))
        .collect();

    // 1. Placement enumeration across every preset × shape.
    let mut placements_total = 0usize;
    for c in &clusters {
        for p in &shapes {
            placements_total += placement::enumerate(c, p).len();
        }
    }
    let enumerate = bench(
        &format!("placement-enumerate/{}shapes-x{}fabrics", shapes.len(), fabrics.len()),
        10,
        50,
        || {
            for c in &clusters {
                for p in &shapes {
                    black_box(placement::enumerate(c, p));
                }
            }
        },
    );

    // 2. Collective pricing over the placed paths (the per-candidate
    // hot cost the search pays on tiered fabrics).
    let hgx = ClusterSpec::with_fabric(h100_sxm(), 8, 4, fabric::hgx_h100());
    let price = bench("collective-price/hgx-h100-16gpu", 10, 50, || {
        for bytes in [4096.0, 1048576.0, 3.3e7, 1e9] {
            black_box(comm::allreduce_placed_us(&hgx, bytes, 16, 2, 4));
            black_box(comm::alltoall_placed_us(&hgx, bytes, 16, 2, 4));
            black_box(comm::allgather_placed_us(&hgx, bytes, 16, 2, 4));
        }
    });

    // 3. Structural-grid build: placement axis on vs off.
    let model = by_name("qwen3-32b").unwrap();
    let space = SearchSpace::default_for(&model, Framework::TrtLlm);
    let legacy = ClusterSpec::new(h100_sxm(), 8, 2);
    let tiered = ClusterSpec::with_fabric(h100_sxm(), 8, 2, fabric::hgx_h100());
    let wl = aiconfigurator::config::WorkloadSpec::new("qwen3-32b", 2048, 256, 2000.0, 20.0);
    let n_legacy = space.engine_grid(&model, &legacy, &wl).len();
    let n_tiered = space.engine_grid(&model, &tiered, &wl).len();
    let grid_legacy = bench_items("engine-grid/legacy-2node", 3, 20, n_legacy, || {
        black_box(space.engine_grid(&model, &legacy, &wl));
    });
    let grid_tiered = bench_items("engine-grid/hgx-h100-2node", 3, 20, n_tiered, || {
        black_box(space.engine_grid(&model, &tiered, &wl));
    });
    println!(
        "    -> grid {} engines (legacy) vs {} engines (tiered, placement axis on)",
        n_legacy, n_tiered
    );

    // Record the run (cwd is rust/ under `cargo bench`).
    let mut o = Json::obj();
    o.set("bench", json::s("topology"))
        .set(
            "fabrics",
            Json::Arr(fabrics.iter().map(|f| json::s(f.name)).collect()),
        )
        .set("shapes", json::num(shapes.len() as f64))
        .set("placements_total", json::num(placements_total as f64))
        .set("enumerate_ms_median", json::num(enumerate.median_ms()))
        .set("collective_price_ms_median", json::num(price.median_ms()))
        .set("grid_legacy_ms_median", json::num(grid_legacy.median_ms()))
        .set("grid_tiered_ms_median", json::num(grid_tiered.median_ms()))
        .set("grid_legacy_engines", json::num(n_legacy as f64))
        .set("grid_tiered_engines", json::num(n_tiered as f64))
        // Raw-speed figures the perf budgets track: grid candidates
        // enumerated (flags resolved, placements expanded) per second.
        .set(
            "grid_legacy_candidates_per_s",
            json::num(grid_legacy.throughput_per_s().unwrap_or(0.0)),
        )
        .set(
            "grid_tiered_candidates_per_s",
            json::num(grid_tiered.throughput_per_s().unwrap_or(0.0)),
        );
    std::fs::write("../BENCH_topology.json", o.to_string()).expect("write BENCH_topology.json");
    println!("    -> wrote ../BENCH_topology.json");
}

//! Bench: end-to-end experiment harness timings (one timed pass per
//! paper table/figure, quick grids) — regenerates each table/figure and
//! reports how long the full regeneration takes.
//!
//! Run: `cargo bench --bench experiments`

use aiconfigurator::experiments::*;
use aiconfigurator::util::bench::once;

fn main() {
    let r1 = once("experiment/fig1-pareto(quick)", || {
        let rep = fig1_pareto::run(true);
        print!("{}", rep.render());
    });
    let r5 = once("experiment/fig5-powerlaw", || {
        let rep = fig5_powerlaw::run(true);
        print!("{}", rep.render());
    });
    let r6 = once("experiment/fig6-agg-fidelity(quick)", || {
        let rep = fig6_agg_fidelity::run(true);
        print!("{}", rep.render());
    });
    let r7 = once("experiment/fig7-disagg-fidelity(quick)", || {
        let rep = fig7_disagg_fidelity::run(true);
        print!("{}", rep.render());
    });
    let r8 = once("experiment/fig8-case-study(quick)", || {
        let rep = fig8_case_study::run(true);
        print!("{}", rep.render());
    });
    let rt = once("experiment/table1-efficiency(quick)", || {
        let rep = table1_efficiency::run(true);
        print!("{}", rep.render());
    });
    println!("\n--- summary (ms) ---");
    for r in [r1, r5, r6, r7, r8, rt] {
        println!("{:<44} {:>12.1}", r.name, r.median_ms());
    }
}

//! Bench: multi-scenario batch sweep (`TaskRunner::run_sweep`) vs the
//! same scenarios priced by independent `run` calls. The sweep shares
//! one structural engine enumeration and a memoized oracle across
//! scenarios, so repeated operator shapes are priced once — the
//! acceptance check is that sweeping ≥4 scenarios beats 4 independent
//! runs on wall-clock.
//!
//! Also compares **point vs batched** oracle pricing on a fixed op
//! list: `op_latency_us` in a loop (one table lookup + placement
//! factor per call) against one `latency_batch` call (queries bucketed
//! per table, each slab walked once) — the §Perf raw-speed win the
//! perf budgets track.
//!
//! Run: `cargo bench --bench sweep`

use aiconfigurator::config::WorkloadSpec;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::ops::{decompose, Op, StepShape};
use aiconfigurator::perfdb::{LatencyOracle, MemoOracle, PerfDatabase};
use aiconfigurator::search::{SearchSpace, TaskRunner};
use aiconfigurator::silicon::Silicon;
use aiconfigurator::util::bench::{bench_items, black_box};

fn scenarios(model: &str) -> Vec<WorkloadSpec> {
    // A realistic SLA exploration: same traffic profile family, varied
    // latency targets plus one longer-context scenario — heavy operator
    // overlap for the memo, distinct memory pruning per scenario.
    vec![
        WorkloadSpec::new(model, 2048, 256, 1500.0, 20.0),
        WorkloadSpec::new(model, 2048, 256, 1000.0, 40.0),
        WorkloadSpec::new(model, 2048, 256, f64::INFINITY, 0.0),
        WorkloadSpec::new(model, 4096, 256, 2000.0, 30.0),
    ]
}

fn main() {
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    for name in ["llama3.1-8b", "qwen3-32b"] {
        let model = by_name(name).unwrap();
        let silicon = Silicon::new(cluster, Framework::TrtLlm.profile());
        let db = PerfDatabase::build(&silicon, &model, Dtype::Fp8, 1);
        let space = SearchSpace::default_for(&model, Framework::TrtLlm);
        let wls = scenarios(name);

        // Candidate count for the throughput figure (one unmeasured
        // sweep; the sweep itself is deterministic).
        let candidates: usize = {
            let runner = TaskRunner::new(&model, &cluster, space.clone(), wls[0].clone());
            runner.run_sweep(&db, &wls).iter().map(|r| r.configs_priced).sum()
        };

        let indep = bench_items(
            &format!("independent-runs-x{}/{name}", wls.len()),
            1,
            8,
            candidates,
            || {
                for wl in &wls {
                    let runner = TaskRunner::new(&model, &cluster, space.clone(), wl.clone());
                    black_box(runner.run(&db));
                }
            },
        );
        let swept = bench_items(
            &format!("run-sweep-x{}/{name}", wls.len()),
            1,
            8,
            candidates,
            || {
                let runner = TaskRunner::new(&model, &cluster, space.clone(), wls[0].clone());
                black_box(runner.run_sweep(&db, &wls));
            },
        );
        println!(
            "    -> run_sweep vs {} independent runs: {:.2}x",
            wls.len(),
            indep.median_ms() / swept.median_ms()
        );

        // Point vs batched oracle pricing over a realistic op list:
        // every engine shape in the default grid, decomposed at a
        // prefill and a decode step (placement factors and table
        // bucketing exercised exactly as the estimators do).
        let mut ops: Vec<Op> = Vec::new();
        for eng in space.engine_grid(&model, &cluster, &wls[0]).iter().take(16) {
            for shape in [StepShape::prefill(1, 2048, 2048), StepShape::decode(32, 2048)] {
                ops.extend(decompose(&model, &cluster, eng, &shape, 1.0));
            }
        }
        let point = bench_items(&format!("oracle-point-x{}/{name}", ops.len()), 3, 20, ops.len(), || {
            for op in &ops {
                black_box(db.op_latency_us(op));
            }
        });
        let batched =
            bench_items(&format!("oracle-batched-x{}/{name}", ops.len()), 3, 20, ops.len(), || {
                black_box(db.latency_batch(&ops));
            });
        println!(
            "    -> batched vs point pricing over {} ops: {:.2}x",
            ops.len(),
            point.median_ms() / batched.median_ms()
        );

        // Memo effectiveness on this space (one sweep, fresh cache).
        let memo = MemoOracle::new(&db as &dyn LatencyOracle);
        for wl in &wls {
            let r = TaskRunner::new(&model, &cluster, space.clone(), wl.clone());
            black_box(r.run(&memo));
        }
        let (hits, misses) = memo.stats();
        println!(
            "    -> oracle memo: {} distinct ops, {:.1}% hit rate over {} queries",
            memo.len(),
            100.0 * hits as f64 / (hits + misses).max(1) as f64,
            hits + misses
        );
    }
}

//! Bench: capacity planner — cold plan (fresh per-leg memos each call,
//! the CLI path) vs memo-warm plan (caller-owned memos reused across
//! plans, the service path). The plan itself is deterministic either
//! way; the warm path skips re-pricing operator latencies, so repeated
//! what-if planning (different traffic curves, same fleet) gets cheap.
//!
//! Run: `cargo bench --bench planner` (or `make bench-plan`).
//! Writes the measured medians to ../BENCH_plan.json.

use aiconfigurator::config::WorkloadSpec;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{a100_sxm, h100_sxm, ClusterSpec};
use aiconfigurator::models::by_name;
use aiconfigurator::perfdb::{LatencyOracle, MemoOracle, PerfDatabase};
use aiconfigurator::planner::{self, PlanSpec, TrafficModel};
use aiconfigurator::silicon::Silicon;
use aiconfigurator::util::bench::{bench, black_box};
use aiconfigurator::util::json::{self, Json};

fn main() {
    let model_name = "llama3.1-8b";
    let model = by_name(model_name).unwrap();
    let framework = Framework::TrtLlm;
    let legs = [ClusterSpec::new(h100_sxm(), 8, 1), ClusterSpec::new(a100_sxm(), 8, 1)];

    // Databases are the offline artifact; build once outside the timers
    // (Ampere profiles fp16 — no fp8 on that part).
    let dbs: Vec<PerfDatabase> = legs
        .iter()
        .map(|c| {
            let sil = Silicon::new(*c, framework.profile());
            PerfDatabase::build(&sil, &model, c.gpu.preferred_kv_dtype(), 0xA1C0)
        })
        .collect();
    let fleet: Vec<(ClusterSpec, &dyn LatencyOracle)> =
        legs.iter().zip(&dbs).map(|(c, d)| (*c, d as &dyn LatencyOracle)).collect();

    let spec = PlanSpec::new(
        WorkloadSpec::new(model_name, 2048, 256, 2000.0, 20.0),
        TrafficModel::Diurnal { peak_qps: 300.0, trough_qps: 10.0, period_h: 24.0 },
        24,
        1.0,
    );

    let windows = spec.windows;
    let cold = bench(&format!("plan-cold-{windows}w-2legs/{model_name}"), 1, 8, || {
        black_box(planner::plan(&model, framework, &spec, &fleet).unwrap());
    });

    // Warm path: per-leg memos owned by the caller, reused across plans.
    let memos: Vec<MemoOracle> =
        fleet.iter().map(|(_, oracle)| MemoOracle::new(*oracle)).collect();
    let warm_fleet: Vec<(ClusterSpec, &MemoOracle)> =
        legs.iter().zip(&memos).map(|(c, m)| (*c, m)).collect();
    // Prime the memos once (unmeasured), then measure steady state.
    let plan = planner::plan_cached(&model, framework, &spec, &warm_fleet).unwrap();
    let warm = bench(&format!("plan-warm-{windows}w-2legs/{model_name}"), 1, 8, || {
        black_box(planner::plan_cached(&model, framework, &spec, &warm_fleet).unwrap());
    });
    println!(
        "    -> memo-warm vs cold plan: {:.2}x  (per-leg memo hit rates: {})",
        cold.median_ms() / warm.median_ms(),
        legs.iter()
            .zip(&memos)
            .map(|(c, m)| format!("{} {:.1}%", c.gpu.name, 100.0 * m.hit_rate()))
            .collect::<Vec<_>>()
            .join(", ")
    );
    println!(
        "    -> schedule: ${:.2} total | static peak ${:.2} ({:.0}% saved) | {} options, {} pruned",
        plan.total_cost_usd,
        plan.static_peak_cost_usd,
        100.0 * plan.elastic_savings_frac(),
        plan.options_considered,
        plan.options_pruned
    );
    if let Some((gpu, cost)) = &plan.best_homogeneous {
        println!(
            "    -> heterogeneity dividend vs all-{gpu}: ${:.2}",
            cost - plan.total_cost_usd
        );
    }

    // Record the run (cwd is rust/ under `cargo bench`).
    let mut o = Json::obj();
    o.set("bench", json::s("planner"))
        .set("model", json::s(model_name))
        .set("fleet", json::arr([json::s("h100-sxm"), json::s("a100-sxm")]))
        .set("windows", json::num(windows as f64))
        .set("cold_plan_ms_median", json::num(cold.median_ms()))
        .set("warm_plan_ms_median", json::num(warm.median_ms()))
        .set("warm_speedup", json::num(cold.median_ms() / warm.median_ms()))
        .set("total_cost_usd", json::num(plan.total_cost_usd))
        .set("static_peak_cost_usd", json::num(plan.static_peak_cost_usd))
        .set("options_considered", json::num(plan.options_considered as f64))
        .set("options_pruned", json::num(plan.options_pruned as f64))
        // Raw-speed figure the perf budgets track: planner options
        // priced per second on the cold (fresh-memo) path.
        .set(
            "cold_plan_options_per_s",
            json::num(plan.options_considered as f64 / (cold.median_ms() / 1e3).max(1e-12)),
        );
    if let Some((gpu, cost)) = &plan.best_homogeneous {
        o.set("best_homogeneous_gpu", json::s(gpu))
            .set("heterogeneity_dividend_usd", json::num(cost - plan.total_cost_usd));
    }
    match std::fs::write("../BENCH_plan.json", o.to_string()) {
        Ok(()) => println!("    -> wrote ../BENCH_plan.json"),
        Err(e) => println!("    -> could not write ../BENCH_plan.json: {e}"),
    }
}

//! Config-search service over real TCP: bind on an ephemeral port,
//! concurrent clients, malformed input, shutdown.

use aiconfigurator::config::WorkloadSpec;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::service::{make_request, make_request_v2, Client, SearchServer, ServerConfig};
use aiconfigurator::util::json;

fn start_server() -> (std::net::SocketAddr, std::sync::Arc<std::sync::atomic::AtomicBool>, std::thread::JoinHandle<anyhow::Result<()>>) {
    let cfg = ServerConfig { addr: "127.0.0.1:0".into(), seed: 7, ..Default::default() };
    let (server, addr) = SearchServer::bind(&cfg, None).unwrap();
    let stop = server.stopper();
    let handle = std::thread::spawn(move || server.run());
    (addr, stop, handle)
}

fn shutdown(addr: std::net::SocketAddr, stop: &std::sync::atomic::AtomicBool) {
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let _ = std::net::TcpStream::connect(addr);
}

#[test]
fn tcp_roundtrip_and_reuse() {
    let (addr, stop, _h) = start_server();
    let mut client = Client::connect(&addr).unwrap();
    let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
    let req = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1);
    let resp = client.request(&req).unwrap();
    assert_eq!(resp.req_str("status").unwrap(), "ok");
    assert!(resp.req_f64("configs_priced").unwrap() > 0.0);
    // Second request on the same connection (cached DB → much faster).
    let t = std::time::Instant::now();
    let resp2 = client.request(&req).unwrap();
    assert_eq!(resp2.req_str("status").unwrap(), "ok");
    assert!(t.elapsed().as_secs_f64() < 5.0);
    shutdown(addr, &stop);
}

#[test]
fn concurrent_clients_get_consistent_answers() {
    let (addr, stop, _h) = start_server();
    let mut handles = Vec::new();
    for i in 0..3u64 {
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let wl = WorkloadSpec::new("llama3.1-8b", 512, 64, 3000.0, 5.0);
            let req = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, i);
            let resp = client.request(&req).unwrap();
            assert_eq!(resp.req_str("status").unwrap(), "ok");
            assert_eq!(resp.req_f64("id").unwrap(), i as f64);
            resp.req("top").unwrap().as_arr().unwrap()[0]
                .req_f64("thru_per_gpu")
                .unwrap()
        }));
    }
    let answers: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // Deterministic pipeline → identical recommendations.
    assert!(answers.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-9), "{answers:?}");
    shutdown(addr, &stop);
}

#[test]
fn malformed_requests_yield_errors_not_disconnects() {
    let (addr, stop, _h) = start_server();
    let mut client = Client::connect(&addr).unwrap();
    // Invalid JSON.
    let resp = client.request(&json::parse(r#"{"workload": 7}"#).unwrap()).unwrap();
    assert_eq!(resp.req_str("status").unwrap(), "error");
    // Unknown model.
    let wl = WorkloadSpec::new("gpt-5", 100, 10, 1000.0, 1.0);
    let resp = client
        .request(&make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 9))
        .unwrap();
    assert_eq!(resp.req_str("status").unwrap(), "error");
    assert!(resp.req_str("error").unwrap().contains("gpt-5"));
    // Connection still usable.
    let wl = WorkloadSpec::new("llama3.1-8b", 256, 32, 5000.0, 1.0);
    let ok = client
        .request(&make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 10))
        .unwrap();
    assert_eq!(ok.req_str("status").unwrap(), "ok");
    shutdown(addr, &stop);
}

#[test]
fn v2_protocol_smoke_over_tcp() {
    use std::io::{BufRead, BufReader, Write};
    let (addr, stop, _h) = start_server();
    let mut client = Client::connect(&addr).unwrap();
    let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);

    // v1 and v2 answer the same search; only the envelope tag differs.
    let v1 = client.request(&make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1)).unwrap();
    let v2 = client.request(&make_request_v2(&wl, "h100", 8, 1, Framework::TrtLlm, 2)).unwrap();
    assert_eq!(v1.req_f64("v").unwrap(), 1.0);
    assert_eq!(v2.req_f64("v").unwrap(), 2.0);
    assert_eq!(v2.req_f64("id").unwrap(), 2.0);
    assert_eq!(v1.req_f64("feasible").unwrap(), v2.req_f64("feasible").unwrap());

    // Unsupported version → typed error, connection survives.
    let resp = client.request(&json::parse(r#"{"v": 3, "op": "search", "id": 4}"#).unwrap()).unwrap();
    assert_eq!(resp.req_str("status").unwrap(), "error");
    assert_eq!(resp.req("error").unwrap().req_str("code").unwrap(), "unsupported_version");
    assert_eq!(resp.req_f64("id").unwrap(), 4.0);

    // A line of invalid UTF-8 gets a typed reply instead of killing the
    // connection loop (raw socket: Client only writes valid JSON).
    let mut raw = std::net::TcpStream::connect(addr).unwrap();
    raw.write_all(&[0xff, 0xfe, 0xfd, b'\n']).unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = json::parse(line.trim()).unwrap();
    assert_eq!(resp.req_str("status").unwrap(), "error");
    assert_eq!(resp.req("error").unwrap().req_str("code").unwrap(), "bad_request");
    // ...and the same connection still answers real requests.
    raw.write_all(b"not json either\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(json::parse(line.trim()).unwrap().req_str("status").unwrap(), "error");

    // The stats request reports what this test did.
    let stats = client.request(&json::parse(r#"{"v": 2, "op": "stats", "id": 9}"#).unwrap()).unwrap();
    assert_eq!(stats.req_str("status").unwrap(), "ok");
    let s = stats.req("stats").unwrap();
    assert!(s.req("requests").unwrap().req("search").unwrap().req_f64("count").unwrap() >= 2.0);
    assert!(s.req("requests").unwrap().req("search").unwrap().req_f64("p50_ms").unwrap() > 0.0);
    assert!(s.req("malformed").unwrap().as_f64().unwrap() >= 2.0);
    assert!(s.req("pool").unwrap().req_f64("workers").unwrap() >= 1.0);
    assert_eq!(s.req("cache").unwrap().req_f64("entries").unwrap(), 1.0);
    assert!(stats.req_str("metrics_text").unwrap().contains("aiconf_shed_total"));
    shutdown(addr, &stop);
}

#[test]
fn launch_bundle_in_response() {
    let (addr, stop, _h) = start_server();
    let mut client = Client::connect(&addr).unwrap();
    let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 3000.0, 10.0);
    let resp = client
        .request(&make_request(&wl, "h100", 8, 1, Framework::Vllm, 2))
        .unwrap();
    let launch = resp.req("launch").unwrap();
    // vLLM aggregated winner → a launch script with vllm serve; disagg →
    // a dynamo yaml. Either way the bundle is non-empty.
    match launch {
        aiconfigurator::util::json::Json::Obj(m) => assert!(!m.is_empty()),
        _ => panic!("launch should be an object"),
    }
    shutdown(addr, &stop);
}

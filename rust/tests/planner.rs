//! Integration tests for the traffic-aware capacity planner: the
//! end-to-end pipeline (PerfDatabase oracle → sweep → options →
//! schedule), pinned against literal brute-force enumeration of every
//! schedule on a small grid, plus the heterogeneous-fleet path.

use aiconfigurator::config::{ServingMode, WorkloadSpec};
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{a100_sxm, h100_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::perfdb::{LatencyOracle, PerfDatabase};
use aiconfigurator::planner::{self, PlanSpec, TrafficModel};
use aiconfigurator::search::{SearchSpace, TaskRunner};
use aiconfigurator::silicon::Silicon;

/// Small-grid option set priced through the real pipeline (database
/// oracle, aggregated mode only so the brute force stays tiny).
fn small_grid_options(
    wl: &WorkloadSpec,
) -> Vec<planner::PricedOption> {
    let model = by_name("llama3.1-8b").unwrap();
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
    let db = PerfDatabase::build(&sil, &model, Dtype::Fp8, 0xA1C0);
    let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
    space.batch = vec![8, 32];
    space.modes = vec![ServingMode::Aggregated];
    let runner = TaskRunner::new(&model, &cluster, space, wl.clone());
    let report = runner.run(&db as &dyn LatencyOracle);
    planner::options_from_report(&cluster.gpu, wl, &report)
}

/// The planner's schedule is exactly the brute-force minimum over the
/// full cross-product of (option per window) schedules. (Replica counts
/// above the ceiling minimum only ever add cost, so the minimal count
/// per pair is the only candidate worth enumerating.)
#[test]
fn plan_matches_bruteforce_enumeration_on_small_grid() {
    let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
    let opts = small_grid_options(&wl);
    let n = opts.len();
    assert!(n >= 2, "grid too small to be interesting: {n}");
    assert!(n <= 16, "grid too big to brute-force: {n}");

    let demands = [40.0, 3.0, 0.0, 90.0];
    let window_h = 1.0;
    let sched = planner::optimize(&opts, &demands, window_h, None);
    for c in &sched.choices {
        assert!(c.is_some());
    }

    // Odometer over every option assignment (n^4 schedules).
    let mut idx = vec![0usize; demands.len()];
    let mut best_total = f64::INFINITY;
    loop {
        let mut total = 0.0;
        for (w, &d) in demands.iter().enumerate() {
            let o = &opts[idx[w]];
            let r = planner::replicas_needed(d, o.qps_per_unit)
                .expect("small-grid demands fit u32 replica counts");
            total += r as f64 * o.usd_per_hour * window_h;
        }
        if total < best_total {
            best_total = total;
        }
        let mut k = 0;
        while k < idx.len() {
            idx[k] += 1;
            if idx[k] < n {
                break;
            }
            idx[k] = 0;
            k += 1;
        }
        if k == idx.len() {
            break;
        }
    }
    assert!(
        (sched.total_cost_usd - best_total).abs() < 1e-9,
        "planner {} vs brute force {}",
        sched.total_cost_usd,
        best_total
    );

    // The k-objective-pruned schedule is the same schedule.
    let kept = planner::prune_options(&opts);
    let pruned: Vec<planner::PricedOption> = kept.iter().map(|&i| opts[i].clone()).collect();
    let ps = planner::optimize(&pruned, &demands, window_h, None);
    assert_eq!(ps.total_cost_usd, sched.total_cost_usd);
    for (a, b) in sched.choices.iter().zip(&ps.choices) {
        let (a, b) = (a.unwrap(), b.unwrap());
        assert_eq!(a.option, kept[b.option]);
        assert_eq!(a.replicas, b.replicas);
    }
}

/// End-to-end heterogeneous plan over two GPU types: every window
/// feasible, and mixing never loses to the best homogeneous schedule
/// (the strict-win case is pinned in `planner::schedule`'s unit tests).
#[test]
fn heterogeneous_fleet_plans_end_to_end() {
    let model = by_name("llama3.1-8b").unwrap();
    let legs = [ClusterSpec::new(h100_sxm(), 8, 1), ClusterSpec::new(a100_sxm(), 8, 1)];
    let sils: Vec<Silicon> =
        legs.iter().map(|c| Silicon::new(*c, Framework::TrtLlm.profile())).collect();
    let fleet: Vec<(ClusterSpec, &dyn LatencyOracle)> =
        legs.iter().zip(&sils).map(|(c, s)| (*c, s as &dyn LatencyOracle)).collect();
    let spec = PlanSpec::new(
        WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0),
        TrafficModel::Bursty { base_qps: 4.0, burst_qps: 150.0, burst_prob: 0.3, seed: 17 },
        12,
        2.0,
    );
    let p = planner::plan(&model, Framework::TrtLlm, &spec, &fleet).unwrap();
    assert_eq!(p.windows.len(), 12);
    // Options came from both legs.
    assert!(p.options_considered > 0);
    for w in &p.windows {
        assert!(w.capacity_qps >= w.demand_qps);
        assert!(w.gpu == "h100-sxm" || w.gpu == "a100-sxm", "{}", w.gpu);
    }
    if let Some((_, homo_cost)) = &p.best_homogeneous {
        assert!(p.total_cost_usd <= homo_cost + 1e-9);
    }
    assert!(p.total_cost_usd <= p.static_peak_cost_usd + 1e-9);

    // JSON surface carries the schedule.
    let j = p.to_json(&spec.workload);
    assert_eq!(j.req("windows").unwrap().as_arr().unwrap().len(), 12);
    assert!(j.req_f64("elastic_savings_frac").unwrap() >= 0.0);
}

//! Randomized property tests over coordinator invariants (home-grown
//! harness over the deterministic RNG — proptest is not in the vendored
//! crate set, see DESIGN.md). Each property runs hundreds of randomized
//! cases; failures print the violating case.

use aiconfigurator::config::{EngineConfig, ParallelSpec, RuntimeFlags, Sla, WorkloadSpec};
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::pareto;
use aiconfigurator::perfdb::query::{flat, trilinear};
use aiconfigurator::perfdb::tables::{GRID_LEN, NX, NY, NZ};
use aiconfigurator::perfmodel::{memory, moe, PerfEstimate};
use aiconfigurator::search::runner::Evaluated;
use aiconfigurator::simulator::kvcache::KvPool;
use aiconfigurator::util::json::{self, Json};
use aiconfigurator::util::rng::Rng;

/// Interpolation output is bounded by the table's min/max (no over- or
/// under-shoot: trilinear is a convex combination of corner values).
#[test]
fn prop_interp_within_table_bounds() {
    let mut rng = Rng::new(0xB0B);
    let mut grids = vec![0f32; GRID_LEN];
    for v in grids.iter_mut() {
        *v = (rng.f64() * 1e4) as f32;
    }
    for _ in 0..500 {
        let t = rng.below(16) as usize;
        let fx = rng.f64() * 40.0 - 4.0; // deliberately out of range too
        let fy = rng.f64() * 40.0 - 4.0;
        let fz = rng.f64() * 20.0 - 2.0;
        let v = trilinear(&grids, t, fx, fy, fz);
        let base = t * NX * NY * NZ;
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &g in &grids[base..base + NX * NY * NZ] {
            lo = lo.min(g);
            hi = hi.max(g);
        }
        assert!(
            v >= lo as f64 - 1e-3 && v <= hi as f64 + 1e-3,
            "t={t} ({fx},{fy},{fz}): {v} outside [{lo},{hi}]"
        );
    }
}

/// Grid-point queries return stored values exactly.
#[test]
fn prop_interp_interpolates_grid_points_exactly() {
    let mut rng = Rng::new(0xC0C);
    let mut grids = vec![0f32; GRID_LEN];
    for v in grids.iter_mut() {
        *v = (rng.f64() * 100.0) as f32;
    }
    for _ in 0..500 {
        let t = rng.below(16) as usize;
        let (ix, iy, iz) = (rng.below(NX as u64) as usize, rng.below(NY as u64) as usize, rng.below(NZ as u64) as usize);
        let v = trilinear(&grids, t, ix as f64, iy as f64, iz as f64);
        assert_eq!(v as f32, grids[flat(t, ix, iy, iz)]);
    }
}

/// The Pareto frontier equals the brute-force non-dominated set.
#[test]
fn prop_pareto_frontier_equals_bruteforce() {
    let mut rng = Rng::new(0xD0D);
    for case in 0..50 {
        let n = 2 + rng.below(40) as usize;
        let pts: Vec<PerfEstimate> = (0..n)
            .map(|_| PerfEstimate {
                ttft_ms: rng.f64() * 1000.0,
                tpot_ms: 1.0 + rng.f64() * 100.0,
                speed: (rng.f64() * 10.0).round() * 10.0, // ties likely
                thru_per_gpu: (rng.f64() * 10.0).round() * 50.0,
                concurrency: 1,
            })
            .collect();
        let frontier = pareto::frontier_indices(&pts);
        // Brute force: i is on the frontier iff nothing strictly dominates.
        for (i, p) in pts.iter().enumerate() {
            let dominated = pts.iter().enumerate().any(|(j, q)| {
                j != i
                    && q.speed >= p.speed
                    && q.thru_per_gpu >= p.thru_per_gpu
                    && (q.speed > p.speed || q.thru_per_gpu > p.thru_per_gpu)
            });
            let on_frontier = frontier.iter().any(|&k| {
                pts[k].speed == p.speed && pts[k].thru_per_gpu == p.thru_per_gpu
            });
            assert_eq!(
                !dominated, on_frontier,
                "case {case} point {i}: dominated={dominated} frontier={on_frontier}"
            );
        }
    }
}

/// SLA analysis never returns an infeasible best, and ranking is by
/// throughput descending.
#[test]
fn prop_analyze_respects_sla_and_order() {
    let mut rng = Rng::new(0xE0E);
    let eng = EngineConfig {
        framework: Framework::TrtLlm,
        parallel: ParallelSpec::tp(1),
        batch: 1,
        weight_dtype: Dtype::Fp8,
        kv_dtype: Dtype::Fp8,
        flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
        placement: aiconfigurator::topology::Placement::packed(),
    };
    for _ in 0..50 {
        let evs: Vec<Evaluated> = (0..rng.below(30) as usize)
            .map(|_| Evaluated {
                cand: aiconfigurator::config::Candidate::Aggregated { engine: eng, replicas: 1 },
                est: PerfEstimate {
                    ttft_ms: rng.f64() * 2000.0,
                    tpot_ms: 1.0 + rng.f64() * 100.0,
                    speed: rng.f64() * 100.0,
                    thru_per_gpu: rng.f64() * 1000.0,
                    concurrency: 1,
                },
            })
            .collect();
        let sla = Sla { ttft_ms: 500.0 + rng.f64() * 1000.0, min_speed: rng.f64() * 50.0 };
        let a = pareto::analyze(&evs, &sla);
        for e in &a.feasible {
            assert!(e.est.meets(&sla));
        }
        for w in a.feasible.windows(2) {
            assert!(w[0].est.thru_per_gpu >= w[1].est.thru_per_gpu);
        }
    }
}

/// KV pool accounting never exceeds capacity and release restores state.
#[test]
fn prop_kvpool_conservation() {
    let mut rng = Rng::new(0xF0F);
    for _ in 0..100 {
        let cap = 1000 + rng.below(100_000);
        let page = 1 + rng.below(128) as u32;
        let mut pool = KvPool::new(cap, page);
        let mut live: Vec<u64> = Vec::new();
        for _ in 0..200 {
            if rng.below(2) == 0 {
                let tokens = 1 + rng.below(5000);
                if pool.can_reserve(tokens) {
                    pool.reserve(tokens);
                    live.push(tokens);
                }
            } else if let Some(tokens) = live.pop() {
                pool.release(tokens);
            }
            assert!(pool.utilization() <= 1.0 + 1e-9);
        }
        for t in live.drain(..) {
            pool.release(t);
        }
        assert_eq!(pool.used_tokens_upper(), 0, "leaked pages");
    }
}

/// Memory model: weights shrink monotonically with TP; KV capacity grows.
#[test]
fn prop_memory_monotone_in_tp() {
    let mut rng = Rng::new(0x101);
    let models = ["llama3.1-8b", "qwen3-32b", "qwen3-235b", "deepseek-v3"];
    for _ in 0..40 {
        let model = by_name(models[rng.below(4) as usize]).unwrap();
        let dt = [Dtype::Fp16, Dtype::Fp8][rng.below(2) as usize];
        let mk = |tp: u32| EngineConfig {
            framework: Framework::TrtLlm,
            parallel: ParallelSpec::tp(tp),
            batch: 1,
            weight_dtype: dt,
            kv_dtype: dt,
            flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
            placement: aiconfigurator::topology::Placement::packed(),
        };
        let mut last = f64::INFINITY;
        for tp in [1u32, 2, 4, 8] {
            if model.heads % tp as u64 != 0 {
                continue;
            }
            let w = memory::weight_bytes_per_gpu(&model, &mk(tp));
            assert!(w <= last * 1.001, "{}: weights grew at tp={tp}", model.name);
            last = w;
        }
    }
}

/// MoE token counts conserve the total for arbitrary (t, k, alpha).
#[test]
fn prop_moe_counts_conserve() {
    let mut rng = Rng::new(0x202);
    for _ in 0..200 {
        let e = 1 + rng.below(256) as usize;
        let t = 1 + rng.below(1 << 16);
        let k = 1 + rng.below(8);
        let alpha = rng.f64() * 2.0;
        let counts = moe::token_counts(&mut rng, e, alpha, t, k);
        assert_eq!(counts.iter().sum::<u64>(), t * k, "e={e} t={t} k={k} a={alpha}");
    }
}

/// γ ≥ 1 always, and γ = 1 exactly when ep ≤ 1.
#[test]
fn prop_moe_gamma_bounds() {
    let mut rng = Rng::new(0x303);
    for _ in 0..100 {
        let e = 1 + rng.below(256);
        let ep = 1 + rng.below(16) as u32;
        let alpha = rng.f64() * 1.8;
        let g = moe::ep_imbalance(e, alpha, ep, rng.next_u64(), 4);
        assert!(g >= 1.0 - 1e-9, "gamma {g}");
        if ep == 1 {
            assert_eq!(g, 1.0);
        }
        // Hottest GPU cannot exceed ep× the mean.
        assert!(g <= ep as f64 + 1e-9, "gamma {g} > ep {ep}");
    }
}

/// JSON writer/parser round-trip on random values.
#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Rng, depth: u32) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.f64() * 2e6 - 1e6).round() / 16.0),
            3 => Json::Str(format!("s{}-\"é\\{}", rng.below(1000), rng.below(10))),
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut o = Json::obj();
                for i in 0..rng.below(5) {
                    o.set(&format!("k{i}"), random_json(rng, depth - 1));
                }
                o
            }
        }
    }
    let mut rng = Rng::new(0x404);
    for _ in 0..300 {
        let v = random_json(&mut rng, 3);
        let re = json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }
}

/// Eq. 2 throughput is monotone: better TPOT (all else equal) never
/// reduces throughput; more GPUs never increase per-GPU throughput.
#[test]
fn prop_eq2_monotonicity() {
    let mut rng = Rng::new(0x505);
    for _ in 0..200 {
        let ttft = rng.f64() * 2000.0;
        let tpot = 1.0 + rng.f64() * 100.0;
        let batch = 1 + rng.below(256) as u32;
        let osl = 2 + rng.below(2048) as u32;
        let gpus = 1 + rng.below(64) as u32;
        let base = PerfEstimate::from_latencies(ttft, tpot, batch, osl, gpus);
        let faster = PerfEstimate::from_latencies(ttft, tpot * 0.9, batch, osl, gpus);
        assert!(faster.thru_per_gpu >= base.thru_per_gpu);
        let more_gpus = PerfEstimate::from_latencies(ttft, tpot, batch, osl, gpus + 1);
        assert!(more_gpus.thru_per_gpu <= base.thru_per_gpu);
    }
}

/// Workload JSON round-trip for random descriptors.
#[test]
fn prop_workload_roundtrip() {
    let mut rng = Rng::new(0x606);
    for _ in 0..100 {
        let wl = WorkloadSpec::new(
            ["qwen3-32b", "deepseek-v3"][rng.below(2) as usize],
            1 + rng.below(65536) as u32,
            1 + rng.below(8192) as u32,
            (rng.f64() * 10000.0).round(),
            (rng.f64() * 200.0).round(),
        );
        let back = WorkloadSpec::from_json(&wl.to_json()).unwrap();
        assert_eq!(back.model, wl.model);
        assert_eq!(back.isl, wl.isl);
        assert_eq!(back.osl, wl.osl);
        assert_eq!(back.sla.ttft_ms, wl.sla.ttft_ms);
    }
}

/// Cluster link selection: collectives within a node never use IB.
#[test]
fn prop_link_selection() {
    let mut rng = Rng::new(0x707);
    for _ in 0..100 {
        let gpn = 1 + rng.below(16) as u32;
        let nodes = 1 + rng.below(8) as u32;
        let c = ClusterSpec::new(h100_sxm(), gpn, nodes);
        for g in 1..=c.total_gpus() {
            let link = c.link_for(g);
            if g <= gpn {
                assert_eq!(link, aiconfigurator::hardware::LinkKind::NvLink);
            } else {
                assert_eq!(link, aiconfigurator::hardware::LinkKind::InfiniBand);
            }
        }
    }
}

/// 3-objective (−cost, capacity, speed) incremental frontier: the
/// accumulator's kept set reduces to exactly the batch O(n²) dominance
/// filter's frontier, on random sets with deliberate ties/duplicates.
#[test]
fn prop_k_accumulator_matches_batch_dominance_filter() {
    let mut rng = Rng::new(0x3D17);
    for case in 0..120 {
        let n = 1 + rng.below(70) as usize;
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                vec![
                    -(rng.f64() * 4.0).round() * 3.0, // −cost/h
                    (rng.f64() * 4.0).round() * 5.0,  // capacity
                    (rng.f64() * 4.0).round() * 10.0, // speed
                ]
            })
            .collect();
        let mut acc = pareto::FrontierAccumulator::new();
        let kept: Vec<usize> =
            (0..n).filter(|&i| acc.offer_point(&pts[i])).collect();
        assert_eq!(acc.rejected() + kept.len(), n, "case {case}");
        let batch = pareto::k_frontier_indices(&pts);
        for &i in &batch {
            assert!(kept.iter().any(|&k| pts[k] == pts[i]), "case {case}: lost {i}");
        }
        let kept_pts: Vec<Vec<f64>> = kept.iter().map(|&k| pts[k].clone()).collect();
        let sub = pareto::k_frontier_indices(&kept_pts);
        let sub_vals: Vec<&Vec<f64>> = sub.iter().map(|&i| &kept_pts[i]).collect();
        let batch_vals: Vec<&Vec<f64>> = batch.iter().map(|&i| &pts[i]).collect();
        assert_eq!(sub_vals, batch_vals, "case {case}");
    }
}

/// Tracked-mode accumulator under random offer/retract/update
/// interleavings: after every operation, (a) `kept_ids` is exactly the
/// accepted set produced by streaming the *live* arena points through a
/// fresh accumulator's `offer_point` in ascending id order — the
/// planner's conservative kept-set contract — and (b) `frontier_ids`
/// is, as a set of objective vectors, `k_frontier_indices` over the
/// live points. Retractions must re-admit formerly-dominated survivors:
/// the schedule deliberately retracts dominators, so points rejected at
/// offer time re-enter the kept set once their dominator dies.
#[test]
fn prop_tracked_interleavings_match_batch_recompute() {
    let mut rng = Rng::new(0x7AC7);
    for case in 0..60 {
        let mut acc = pareto::FrontierAccumulator::new();
        // The reference arena: (objectives, alive) per stable id.
        let mut arena: Vec<(Vec<f64>, bool)> = Vec::new();
        let rand_pt = |rng: &mut Rng| {
            vec![
                -(rng.f64() * 4.0).round() * 3.0, // −cost/h
                (rng.f64() * 4.0).round() * 5.0,  // capacity
                (rng.f64() * 4.0).round() * 10.0, // speed
                (rng.f64() * 4.0).round() * 2.0,  // −gpus (4-objective)
            ]
        };
        for step in 0..80 {
            match rng.below(4) {
                0 | 1 => {
                    let p = rand_pt(&mut rng);
                    let id = acc.offer_tracked(&p);
                    assert_eq!(id, arena.len(), "case {case} step {step}: id drift");
                    arena.push((p, true));
                }
                2 if !arena.is_empty() => {
                    let id = rng.below(arena.len() as u64) as usize;
                    acc.retract(id);
                    arena[id].1 = false;
                }
                3 if !arena.is_empty() => {
                    let id = rng.below(arena.len() as u64) as usize;
                    let p = rand_pt(&mut rng);
                    acc.update(id, &p);
                    arena[id] = (p, true); // update revives
                }
                _ => continue,
            }
            // (a) kept set == streaming the live points in id order.
            let mut reference = pareto::FrontierAccumulator::new();
            let expect_kept: Vec<usize> = arena
                .iter()
                .enumerate()
                .filter(|(_, (p, alive))| *alive && reference.offer_point(p))
                .map(|(id, _)| id)
                .collect();
            assert_eq!(
                acc.kept_ids(),
                expect_kept,
                "case {case} step {step}: kept set diverged from id-order replay"
            );
            // (b) frontier == batch dominance filter over live points.
            let live: Vec<Vec<f64>> =
                arena.iter().filter(|(_, a)| *a).map(|(p, _)| p.clone()).collect();
            let batch = pareto::k_frontier_indices(&live);
            let mut batch_vals: Vec<&Vec<f64>> = batch.iter().map(|&i| &live[i]).collect();
            let front_pts: Vec<Vec<f64>> =
                acc.frontier_ids().iter().map(|&id| arena[id].0.clone()).collect();
            let mut front_vals: Vec<&Vec<f64>> = front_pts.iter().collect();
            let key = |v: &&Vec<f64>| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
            batch_vals.sort_by_key(key);
            front_vals.sort_by_key(key);
            assert_eq!(
                front_vals, batch_vals,
                "case {case} step {step}: frontier diverged from batch recompute"
            );
            assert_eq!(acc.live_len(), live.len(), "case {case} step {step}");
        }
    }
}

/// Window cost under the ceiling replica rule is nonincreasing when an
/// option weakly dominates another in (−cost, capacity) — the invariant
/// that makes the planner's k-objective prune schedule-transparent.
#[test]
fn prop_dominating_option_never_costs_more_per_window() {
    let mut rng = Rng::new(0xD0C5);
    for _ in 0..500 {
        let cost_a = 1.0 + (rng.f64() * 8.0).round();
        let cap_a = 1.0 + (rng.f64() * 8.0).round();
        // B is weakly dominated: costs at least as much, serves no more.
        let cost_b = cost_a + (rng.f64() * 4.0).round();
        let cap_b = (cap_a - (rng.f64() * 4.0).round()).max(0.5);
        let demand = rng.f64() * 50.0;
        let n = |d: f64, c: f64| if d <= 0.0 { 0.0 } else { (d / c).ceil() };
        assert!(
            n(demand, cap_a) * cost_a <= n(demand, cap_b) * cost_b + 1e-12,
            "d={demand} A=({cost_a},{cap_a}) B=({cost_b},{cap_b})"
        );
    }
}

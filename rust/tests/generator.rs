//! Generator integration (paper §4.1 step 5): recommended candidates
//! become complete, mutually consistent launch bundles on disk for every
//! backend, including the Dynamo disaggregated deployment spec.

use aiconfigurator::config::{
    Candidate, EngineConfig, ParallelSpec, RuntimeFlags, WorkloadSpec,
};
use aiconfigurator::frameworks::Framework;
use aiconfigurator::generator;
use aiconfigurator::models::Dtype;

fn eng(fw: Framework, tp: u32, batch: u32) -> EngineConfig {
    EngineConfig {
        framework: fw,
        parallel: ParallelSpec::tp(tp),
        batch,
        weight_dtype: Dtype::Fp8,
        kv_dtype: Dtype::Fp8,
        flags: RuntimeFlags::defaults_for(fw),
        placement: aiconfigurator::topology::Placement::packed(),
    }
}

fn wl() -> WorkloadSpec {
    WorkloadSpec::new("qwen3-32b", 4000, 500, 1200.0, 60.0)
}

#[test]
fn bundle_written_to_disk_and_complete() {
    let cand = Candidate::Disaggregated {
        prefill: eng(Framework::TrtLlm, 1, 1),
        decode: eng(Framework::TrtLlm, 2, 80),
        x: 4,
        y: 2,
    };
    let bundle = generator::generate(&cand, "Qwen/Qwen3-32B-FP8", &wl());
    let dir = std::env::temp_dir().join(format!("aiconf_gen_{}", std::process::id()));
    bundle.write_to(&dir).unwrap();
    for (name, content) in &bundle.files {
        let on_disk = std::fs::read_to_string(dir.join(name)).unwrap();
        assert_eq!(&on_disk, content, "{name} content mismatch");
    }
    // Paper's Table 2 shape: P:4xTP1, D:2xTP2, decode batch 80.
    let y = bundle.get("dynamo_disagg.yaml").unwrap();
    assert!(y.contains("replicas: 4") && y.contains("replicas: 2"));
    assert!(y.contains("max_batch_size: 80"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn flags_propagate_for_each_backend() {
    let w = wl();
    // TRT-LLM: kv fraction + chunked context + cuda graph flags.
    let mut e = eng(Framework::TrtLlm, 4, 32);
    e.flags.kv_frac = 0.77;
    e.flags.cuda_graph = false;
    let b = generator::generate(&Candidate::Aggregated { engine: e, replicas: 2 }, "m", &w);
    let sh = b.get("launch_server.sh").unwrap();
    assert!(sh.contains("0.77"));
    let yml = b.get("trtllm_server.yaml").unwrap();
    assert!(yml.contains("cuda_graph_config: null"));

    // vLLM: enforce-eager when graphs are off; chunked prefill flag.
    let mut e = eng(Framework::Vllm, 2, 64);
    e.flags.cuda_graph = false;
    e.flags.chunked_prefill = false;
    let b = generator::generate(&Candidate::Aggregated { engine: e, replicas: 1 }, "m", &w);
    let sh = b.get("launch_server.sh").unwrap();
    assert!(sh.contains("--enforce-eager"));
    assert!(!sh.contains("--enable-chunked-prefill"));

    // SGLang: ep-size and chunk size surface.
    let mut e = eng(Framework::Sglang, 8, 16);
    e.parallel.ep = 8;
    let b = generator::generate(&Candidate::Aggregated { engine: e, replicas: 1 }, "m", &w);
    let sh = b.get("launch_server.sh").unwrap();
    assert!(sh.contains("--ep-size 8"));
}

#[test]
fn workload_context_embedded() {
    let w = wl();
    for fw in Framework::all() {
        let b = generator::generate(
            &Candidate::Aggregated { engine: eng(fw, 2, 8), replicas: 1 },
            "org/model-x",
            &w,
        );
        let sh = b.get("launch_server.sh").unwrap();
        assert!(sh.contains("ISL=4000"), "{fw:?}");
        assert!(sh.contains("org/model-x"), "{fw:?}");
    }
}

#[test]
fn end_to_end_search_to_bundle() {
    // The pipeline's last mile: search result -> launch bundle.
    use aiconfigurator::hardware::{h200_sxm, ClusterSpec};
    use aiconfigurator::models::by_name;
    use aiconfigurator::pareto;
    use aiconfigurator::perfdb::PerfDatabase;
    use aiconfigurator::search::{SearchSpace, TaskRunner};
    use aiconfigurator::silicon::Silicon;

    let model = by_name("qwen3-32b").unwrap();
    let cluster = ClusterSpec::new(h200_sxm(), 8, 1);
    let silicon = Silicon::new(cluster, Framework::TrtLlm.profile());
    let db = PerfDatabase::build(&silicon, &model, Dtype::Fp8, 1);
    let w = wl();
    let report = TaskRunner::new(
        &model,
        &cluster,
        SearchSpace::default_for(&model, Framework::TrtLlm),
        w.clone(),
    )
    .run(&db);
    let analysis = pareto::analyze(&report.evaluated, &w.sla);
    let best = analysis.best().expect("feasible");
    let bundle = generator::generate(&best.cand, "Qwen/Qwen3-32B-FP8", &w);
    assert!(!bundle.files.is_empty());
    // Any launch script mentions the model id.
    assert!(bundle.files.iter().any(|(n, c)| n.ends_with(".sh") && c.contains("Qwen3-32B")));
}

//! Regression gates for the parallel search-engine rework: the batch
//! sweep API must be a pure optimization (bit-identical reports to
//! independent runs), the pooled engine must match the seed baseline,
//! and in-sweep pruning must preserve the analysis outcome.

use aiconfigurator::config::WorkloadSpec;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::pareto;
use aiconfigurator::perfdb::{LatencyOracle, MemoOracle, PerfDatabase};
use aiconfigurator::search::{SearchSpace, TaskRunner};
use aiconfigurator::silicon::Silicon;

fn fixture(model: &str) -> (ClusterSpec, aiconfigurator::models::ModelArch, PerfDatabase) {
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let silicon = Silicon::new(cluster, Framework::TrtLlm.profile());
    let m = by_name(model).unwrap();
    let db = PerfDatabase::build(&silicon, &m, Dtype::Fp8, 0x5EED);
    (cluster, m, db)
}

fn scenarios(model: &str) -> Vec<WorkloadSpec> {
    vec![
        WorkloadSpec::new(model, 2048, 256, 1500.0, 20.0),
        WorkloadSpec::new(model, 2048, 256, 1000.0, 40.0),
        WorkloadSpec::new(model, 1024, 128, f64::INFINITY, 0.0),
        WorkloadSpec::new(model, 4096, 256, 2000.0, 10.0),
    ]
}

#[test]
fn run_sweep_equals_independent_runs() {
    let (cluster, model, db) = fixture("llama3.1-8b");
    let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
    space.batch = vec![8, 32, 128];
    space.max_x = 8;
    space.max_y = 8;
    let wls = scenarios("llama3.1-8b");

    let runner = TaskRunner::new(&model, &cluster, space.clone(), wls[0].clone());
    let swept = runner.run_sweep(&db, &wls);
    assert_eq!(swept.len(), wls.len());

    for (wl, sweep_report) in wls.iter().zip(&swept) {
        let single =
            TaskRunner::new(&model, &cluster, space.clone(), wl.clone()).run(&db);
        assert_eq!(
            sweep_report.configs_priced, single.configs_priced,
            "configs priced diverge for isl={} osl={}",
            wl.isl, wl.osl
        );
        assert_eq!(
            sweep_report.evaluated.len(),
            single.evaluated.len(),
            "candidate counts diverge for isl={} osl={}",
            wl.isl,
            wl.osl
        );
        for (a, b) in sweep_report.evaluated.iter().zip(&single.evaluated) {
            assert_eq!(a.cand, b.cand);
            assert_eq!(a.est, b.est, "estimates must be bit-identical (memoized oracle)");
        }
    }
}

#[test]
fn sweep_memo_is_transparent() {
    // A MemoOracle-wrapped run equals the raw-oracle run exactly.
    let (cluster, model, db) = fixture("llama3.1-8b");
    let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
    space.batch = vec![8, 64];
    let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
    let runner = TaskRunner::new(&model, &cluster, space, wl);
    let raw = runner.run(&db);
    let memo = MemoOracle::new(&db as &dyn LatencyOracle);
    let memod = runner.run(&memo);
    assert!(memo.len() > 0, "memo should have been populated");
    for (a, b) in raw.evaluated.iter().zip(&memod.evaluated) {
        assert_eq!(a.est, b.est);
    }
}

#[test]
fn sweep_repeated_scenario_is_cache_hit_identical() {
    // The same scenario twice in one sweep: reports must be identical.
    let (cluster, model, db) = fixture("llama3.1-8b");
    let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
    space.batch = vec![8, 32];
    let wl = WorkloadSpec::new("llama3.1-8b", 2048, 256, 1500.0, 20.0);
    let runner = TaskRunner::new(&model, &cluster, space, wl.clone());
    let reports = runner.run_sweep(&db, &[wl.clone(), wl]);
    assert_eq!(reports[0].evaluated.len(), reports[1].evaluated.len());
    for (a, b) in reports[0].evaluated.iter().zip(&reports[1].evaluated) {
        assert_eq!(a.cand, b.cand);
        assert_eq!(a.est, b.est);
    }
}

#[test]
fn pruned_sweep_preserves_analysis_per_scenario() {
    let (cluster, model, db) = fixture("qwen3-32b");
    let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
    space.batch = vec![8, 32, 128];
    space.max_x = 8;
    space.max_y = 16;
    let wls = scenarios("qwen3-32b");
    let runner = TaskRunner::new(&model, &cluster, space, wls[0].clone());
    let full = runner.run_sweep(&db, &wls);
    let pruned = runner.run_sweep_with(
        &db,
        &wls,
        &aiconfigurator::search::RunOptions { prune: true },
    );
    for ((wl, f), p) in wls.iter().zip(&full).zip(&pruned) {
        let af = pareto::analyze(&f.evaluated, &wl.sla);
        let ap = pareto::analyze(&p.evaluated, &wl.sla);
        assert!(p.evaluated.len() <= f.evaluated.len());
        match (af.best(), ap.best()) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                assert_eq!(a.est.thru_per_gpu, b.est.thru_per_gpu);
                let vals = |a: &pareto::Analysis| -> Vec<(f64, f64)> {
                    a.frontier
                        .iter()
                        .map(|&i| (a.feasible[i].est.speed, a.feasible[i].est.thru_per_gpu))
                        .collect()
                };
                assert_eq!(vals(&af), vals(&ap));
            }
            (a, b) => panic!(
                "pruned feasibility diverged: full={} pruned={}",
                a.is_some(),
                b.is_some()
            ),
        }
    }
}

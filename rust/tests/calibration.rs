//! End-to-end calibration pipeline tests: the committed synthetic
//! measurement set fits and improves per-table fidelity (the same gate
//! CI's `calibration-smoke` job enforces through the CLI), and the
//! three-tier lookup chain (measured cell → calibrated-analytic → SoL)
//! tags provenance correctly all the way up through a TaskRunner
//! search.

use std::path::Path;

use aiconfigurator::config::WorkloadSpec;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::ops::Op;
use aiconfigurator::perfdb::tables::TableId;
use aiconfigurator::perfdb::{calibrate, measure, CalibratedDb, LatencyOracle, PerfDatabase};
use aiconfigurator::search::{SearchSpace, TaskRunner};
use aiconfigurator::silicon::Silicon;

fn h100_ctx(model: &str) -> (Silicon, aiconfigurator::models::ModelArch) {
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    (Silicon::new(cluster, Framework::TrtLlm.profile()), by_name(model).unwrap())
}

/// The acceptance-criteria gate, hermetically: fitting the *committed*
/// measurement set must reduce per-table MAPE vs. the uncalibrated
/// analytic fill. (CI additionally runs the same thing through the
/// `calibrate --check-improves` CLI and uploads the fidelity report.)
#[test]
fn committed_measurement_set_fits_and_improves_every_table() {
    let (sil, model) = h100_ctx("qwen3-32b");
    // Same seed the CLI uses, so this test sees the CLI's database.
    let db = PerfDatabase::build(&sil, &model, Dtype::Fp8, 0xA1C0);
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts/measurements");
    let sets = measure::load_dir(&dir, "h100-sxm").expect("committed measurement set loads");
    assert!(sets.len() >= 6, "committed set covers at least 6 tables, got {}", sets.len());

    let art = calibrate::fit(&db, &sets).unwrap();
    assert_eq!(art.fits.len(), sets.len());
    for f in &art.fits {
        assert!(
            f.pre_mape > 0.05,
            "{}: committed set carries a deliberate bias, pre-MAPE should be visible: {f:?}",
            f.table.name()
        );
        assert!(
            f.post_mape < f.pre_mape,
            "{}: fit must improve fidelity: pre {:.3} post {:.3}",
            f.table.name(),
            f.pre_mape,
            f.post_mape
        );
        assert!(f.n_points >= 40, "{}: {} points survived", f.table.name(), f.n_points);
    }
    assert!(art.all_tables_improve());
    assert!(!art.measured_cells.is_empty(), "grid-point measurements populate the overlay");

    // The artifact round-trips through disk like the CLI writes it.
    let tmp = std::env::temp_dir().join(format!("aicfg_cal_{}.json", std::process::id()));
    art.save(&tmp).unwrap();
    let back = aiconfigurator::perfdb::CalibrationArtifact::load(&tmp).unwrap();
    assert_eq!(back.fits, art.fits);
    let _ = std::fs::remove_file(&tmp);
}

/// Provenance chain: a query at a measured grid point is answered by
/// the measurement itself (beating the calibrated interpolation), an
/// off-grid query by the calibrated grid, a table with no measurements
/// by the plain analytic grid, and non-tabular ops by SoL.
#[test]
fn three_tier_chain_tags_and_prioritizes_correctly() {
    let (sil, model) = h100_ctx("llama3.1-8b");
    let db = PerfDatabase::build(&sil, &model, Dtype::Fp8, 0xBEEF);
    // Measure ONLY the gemm tables: attention stays analytic.
    let all = measure::synthesize_with(&sil, &model, Dtype::Fp8, 17, 32, &|_| (1.3, 0.0), 0.02);
    let sets: Vec<_> = all
        .into_iter()
        .filter(|s| matches!(s.table, TableId::GemmFp16 | TableId::GemmFp8))
        .collect();
    let art = calibrate::fit(&db, &sets).unwrap();
    let plain = db.clone();
    let cal = CalibratedDb::compose(db, &art).unwrap();

    // 1) Measured tier: query exactly at a measured point returns the
    //    stored measurement bit-for-bit (precedence over interpolation).
    let e = sets
        .iter()
        .find(|s| s.table == TableId::GemmFp8)
        .unwrap()
        .entries
        .iter()
        .find(|e| e.x >= 1.0)
        .unwrap();
    let op = Op::Gemm {
        m: e.x.round().max(1.0) as u64,
        n: e.y.round().max(1.0) as u64,
        k: e.z.round().max(1.0) as u64,
        dtype: Dtype::Fp8,
        count: 1,
    };
    let got = cal.op_latency_us(&op);
    assert_eq!(got, e.us, "measured cell must be served verbatim");
    let t = cal.tier_counts();
    assert_eq!((t.measured, t.calibrated, t.analytic, t.sol), (1, 0, 0, 0));

    // 2) Calibrated tier: an off-grid gemm scales by ~the fitted
    //    factor. k=5043 sits mid-cell on the z axis (fractional index
    //    ~10.5), safely outside MEASURED_SNAP of any measured cell.
    let off = Op::Gemm { m: 3333, n: 11111, k: 5043, dtype: Dtype::Fp8, count: 1 };
    let a = plain.op_latency_us(&off);
    let c = cal.op_latency_us(&off);
    assert!((c / a / 1.3 - 1.0).abs() < 0.05, "calibrated ratio {:.3}", c / a);

    // 3) Analytic tier: attention has no measurements — identical to
    //    the uncalibrated database.
    let attn = Op::AttnDecode {
        batch: 32,
        kv_len: 4096,
        heads: 32,
        head_dim: 128,
        kv_token_bytes: 1024.0,
        count: 1,
    };
    assert_eq!(cal.op_latency_us(&attn), plain.op_latency_us(&attn));

    // 4) SoL tier: elementwise bypasses the tables entirely.
    let elem = Op::Elementwise { bytes: 1e8, count: 1 };
    assert_eq!(cal.op_latency_us(&elem), plain.op_latency_us(&elem));

    let t = cal.tier_counts();
    assert_eq!(t.measured, 1);
    assert_eq!(t.calibrated, 1);
    assert_eq!(t.analytic, 1);
    assert_eq!(t.sol, 1);
    assert_eq!(t.total(), 4);
}

/// SearchReport carries per-tier query counts when (and only when) the
/// oracle is calibrated, and calibration shifts absolute estimates
/// without breaking the search.
#[test]
fn search_reports_tier_counts_through_the_runner() {
    let (sil, model) = h100_ctx("llama3.1-8b");
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let db = PerfDatabase::build(&sil, &model, Dtype::Fp8, 0xA1C0);
    let sets = measure::synthesize(&sil, &model, Dtype::Fp8, 23, 24);
    let art = calibrate::fit(&db, &sets).unwrap();
    let cal = CalibratedDb::compose(db.clone(), &art).unwrap();

    let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
    space.batch = vec![8, 32];
    space.max_x = 4;
    space.max_y = 4;
    let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
    let runner = TaskRunner::new(&model, &cluster, space, wl.clone());

    let plain_report = runner.run(&db as &dyn LatencyOracle);
    assert!(plain_report.tier_counts.is_none(), "uncalibrated oracle has no tiers");

    let cal_report = runner.run(&cal);
    let t = cal_report.tier_counts.expect("calibrated oracle reports tiers");
    assert!(t.total() > 0);
    assert!(
        t.calibrated + t.measured > 0,
        "a search over gemm-heavy ops must hit calibrated tiers: {t:?}"
    );
    // Same candidate set either way; only latencies moved.
    assert_eq!(plain_report.evaluated.len(), cal_report.evaluated.len());
    assert_eq!(plain_report.configs_priced, cal_report.configs_priced);

    // Back-to-back runs attribute counts per run (snapshot deltas), so
    // a second identical search reports (close to) the same volume.
    let again = runner.run(&cal).tier_counts.unwrap();
    assert_eq!(again.total(), t.total(), "per-run attribution must not accumulate");
}

/// Sweeps through a memoized oracle still report tiers (unique-shape
/// counts) for every scenario.
#[test]
fn sweep_reports_tiers_under_memoization() {
    let (sil, model) = h100_ctx("llama3.1-8b");
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let db = PerfDatabase::build(&sil, &model, Dtype::Fp8, 0xA1C0);
    let sets = measure::synthesize(&sil, &model, Dtype::Fp8, 29, 16);
    let art = calibrate::fit(&db, &sets).unwrap();
    let cal = CalibratedDb::compose(db, &art).unwrap();

    let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
    space.batch = vec![8, 32];
    space.max_x = 4;
    space.max_y = 4;
    let wls = vec![
        WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0),
        WorkloadSpec::new("llama3.1-8b", 512, 64, 3000.0, 5.0),
    ];
    let runner = TaskRunner::new(&model, &cluster, space, wls[0].clone());
    let reports = runner.run_sweep(&cal, &wls);
    assert_eq!(reports.len(), 2);
    let first = reports[0].tier_counts.expect("memo forwards provenance");
    assert!(first.total() > 0);
    // The second scenario re-hits memoized shapes: its unique-shape
    // count can be small, but the field must still be present.
    assert!(reports[1].tier_counts.is_some());
}

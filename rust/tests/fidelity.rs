//! Fidelity regression gates: the quick variants of the paper's
//! evaluation experiments must stay inside calibrated error envelopes.
//! (Full paper-scale sweeps run via `examples/fidelity_report.rs --full`
//! and are recorded in EXPERIMENTS.md.)

use aiconfigurator::experiments::{
    fig1_pareto, fig5_powerlaw, fig6_agg_fidelity, fig7_disagg_fidelity, fig8_case_study,
    table1_efficiency,
};

#[test]
fn fig6_quick_envelope() {
    let rep = fig6_agg_fidelity::run(true);
    let mape = rep.get("tpot_mape_overall").unwrap();
    assert!(mape < 35.0, "overall TPOT MAPE {mape}% (paper: 7.8%)");
}

#[test]
fn fig7_quick_envelope() {
    let rep = fig7_disagg_fidelity::run(true);
    assert!(rep.get("points").unwrap() >= 1.0, "no frontier points validated");
    assert!(rep.get("speed_mape").unwrap() < 40.0);
}

#[test]
fn fig8_shape_holds() {
    let rep = fig8_case_study::run(true);
    assert!(rep.get("disagg_gain_pct").unwrap() > 25.0, "disagg should win the case study");
    assert!(rep.get("search_s").unwrap() < 30.0);
}

#[test]
fn fig1_crossover_exists() {
    let rep = fig1_pareto::run(true);
    assert!(rep.get("disagg_gain_pct_40").unwrap() > 30.0);
}

#[test]
fn fig5_skew_table() {
    let rep = fig5_powerlaw::run(true);
    assert!(rep.get("top20_share_a1.2").unwrap() > 50.0);
}

#[test]
fn table1_quick_envelope() {
    let rep = table1_efficiency::run(true);
    for m in ["llama3.1-8b", "qwen3-32b", "qwen3-235b"] {
        assert!(rep.get(&format!("speedup_{m}")).unwrap() > 1_000.0);
    }
}

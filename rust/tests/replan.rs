//! Integration tests for differential re-planning over the *committed*
//! delta scenarios (`artifacts/deltas/*.json`) — the same files the CI
//! `replan-smoke` job replays through the CLI. Each scenario's
//! incremental replan must be bit-identical to a from-scratch plan of
//! the patched inputs while re-pricing strictly fewer engine configs
//! than the full re-search (the differential layer's contract).

use std::path::{Path, PathBuf};

use aiconfigurator::config::WorkloadSpec;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{gpu_by_name, parse_fleet_leg, ClusterSpec};
use aiconfigurator::models::by_name;
use aiconfigurator::perfdb::{LatencyOracle, MemoOracle};
use aiconfigurator::planner::{self, PlanSpec, TrafficModel};
use aiconfigurator::search::SearchDelta;
use aiconfigurator::silicon::Silicon;
use aiconfigurator::util::json;

fn repo_root() -> PathBuf {
    // CARGO_MANIFEST_DIR is <repo>/rust.
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}

/// One fleet leg from its `GPU[@FABRIC]` token, priced by the analytic
/// silicon directly (no database build — keeps the scenario loop fast).
fn build_leg(token: &str) -> (ClusterSpec, Silicon) {
    let leg = parse_fleet_leg(token, 8).unwrap_or_else(|e| panic!("leg '{token}': {e}"));
    let cluster = ClusterSpec::with_fabric(leg.gpu, 8, 1, leg.fabric);
    let silicon = Silicon::new(cluster, Framework::TrtLlm.profile());
    (cluster, silicon)
}

/// Every committed delta scenario replans bit-identically to the
/// from-scratch plan of the patched inputs, re-pricing strictly fewer
/// configs than the full re-search. The baseline fleet is h100 + a100 —
/// scenarios may remove `a100`, reprice `h100`, and add legs, but must
/// not recalibrate (the smoke runs without a calibration artifact; the
/// recalibrate path is pinned by the planner's unit tests).
#[test]
fn committed_delta_scenarios_replan_bit_identically() {
    let dir = repo_root().join("artifacts").join("deltas");
    assert!(dir.is_dir(), "artifacts/deltas is committed by this repo and must exist");
    let model = by_name("llama3.1-8b").unwrap();
    let fw = Framework::TrtLlm;
    let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
    let spec = PlanSpec::new(
        wl.clone(),
        TrafficModel::Diurnal { peak_qps: 80.0, trough_qps: 4.0, period_h: 24.0 },
        12,
        1.0,
    );
    let tokens = ["h100", "a100"];

    let mut found = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        if !path.extension().is_some_and(|x| x == "json") {
            continue;
        }
        found += 1;
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        let txt = std::fs::read_to_string(&path).unwrap();
        let j = json::parse(&txt).unwrap_or_else(|e| panic!("{name}: invalid JSON: {e}"));
        let delta = SearchDelta::from_json(&j).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(
            delta.recalibrate.is_empty(),
            "{name}: committed scenarios must not recalibrate — the smoke runs \
             without a calibration artifact"
        );
        for (w, _) in &delta.window_edits {
            assert!(*w < spec.windows, "{name}: window edit {w} outside the smoke horizon");
        }

        // Incremental: baseline arena, then the delta through `replan`.
        let legs: Vec<(ClusterSpec, Silicon)> =
            tokens.iter().map(|t| build_leg(t)).collect();
        let memos: Vec<MemoOracle<'_>> =
            legs.iter().map(|(_, s)| MemoOracle::new(s as &dyn LatencyOracle)).collect();
        let fleet: Vec<(ClusterSpec, &MemoOracle<'_>)> =
            legs.iter().zip(&memos).map(|((c, _), m)| (*c, m)).collect();
        let (baseline, mut arena) = planner::plan_arena(&model, fw, &spec, &fleet)
            .unwrap_or_else(|e| panic!("{name}: baseline plan: {e}"));
        let added: Vec<(ClusterSpec, Silicon)> =
            delta.add_legs.iter().map(|t| build_leg(t)).collect();
        let added_memos: Vec<MemoOracle<'_>> =
            added.iter().map(|(_, s)| MemoOracle::new(s as &dyn LatencyOracle)).collect();
        let swept: Vec<(ClusterSpec, &MemoOracle<'_>)> =
            added.iter().zip(&added_memos).map(|((c, _), m)| (*c, m)).collect();
        let rep = planner::replan(&model, fw, &mut arena, &baseline, &delta, &swept)
            .unwrap_or_else(|e| panic!("{name}: replan: {e}"));
        assert!(
            rep.repriced_configs < rep.baseline_priced_configs,
            "{name}: replan re-priced {} of {} configs — nothing saved",
            rep.repriced_configs,
            rep.baseline_priced_configs
        );

        // From scratch: the patched fleet in canonical order (removed
        // legs dropped, added appended), repriced GPUs, window edits as
        // demand overrides.
        let mut patched: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
        for r in &delta.remove_legs {
            let gpu = gpu_by_name(r).unwrap_or_else(|| panic!("{name}: unknown gpu '{r}'"));
            let pos = patched
                .iter()
                .position(|t| parse_fleet_leg(t, 8).unwrap().gpu.name == gpu.name)
                .unwrap_or_else(|| panic!("{name}: removes '{r}' not in baseline fleet"));
            patched.remove(pos);
        }
        patched.extend(delta.add_legs.iter().cloned());
        let mut fresh: Vec<(ClusterSpec, Silicon)> =
            patched.iter().map(|t| build_leg(t)).collect();
        for (g, price) in &delta.reprice {
            let gpu = gpu_by_name(g).unwrap_or_else(|| panic!("{name}: unknown gpu '{g}'"));
            for (c, _) in fresh.iter_mut() {
                if c.gpu.name == gpu.name {
                    c.gpu.usd_per_hour = *price;
                }
            }
        }
        let fresh_fleet: Vec<(ClusterSpec, &dyn LatencyOracle)> =
            fresh.iter().map(|(c, s)| (*c, s as &dyn LatencyOracle)).collect();
        let mut pspec = spec.clone();
        pspec.demand_override = delta.window_edits.clone();
        let fresh_plan = planner::plan(&model, fw, &pspec, &fresh_fleet)
            .unwrap_or_else(|e| panic!("{name}: from-scratch plan: {e}"));
        assert_eq!(
            rep.plan.to_json(&wl).to_string(),
            fresh_plan.to_json(&wl).to_string(),
            "{name}: incremental replan is not bit-identical to the from-scratch plan"
        );

        // The report's JSON surface carries the diff the CI job uploads.
        let rj = rep.to_json(&wl);
        assert_eq!(rj.req_str("kind").unwrap(), "replan-report", "{name}");
        assert!(rj.req("entered").unwrap().as_arr().is_some(), "{name}");
        assert!(rj.req("left").unwrap().as_arr().is_some(), "{name}");
        assert!(
            rj.req_f64("repriced_configs").unwrap()
                < rj.req_f64("baseline_priced_configs").unwrap(),
            "{name}"
        );
    }
    assert!(found >= 2, "artifacts/deltas holds fewer scenarios than the smoke expects");
}

//! Topology subsystem integration gates.
//!
//! 1. **Pinned legacy equivalence**: `ClusterSpec::new` (the back-compat
//!    constructor) prices every collective bit-for-bit as the seed's
//!    hard-coded formulas — the constants and closed forms are copied
//!    into this file verbatim so a drift in the delegation chain
//!    (`silicon::comm` → `topology::collective`) fails loudly.
//! 2. **Acceptance**: a search over a 2-node tiered fabric prices at
//!    least two *distinct placements* of the same (tp, pp) shape with
//!    different latencies, the chosen placement is visible in the
//!    `SearchReport` candidates, and emitted launch bundles carry it.
//! 3. The profiled database distinguishes placements (packed baseline ×
//!    analytic placement factor) while staying placement-blind on the
//!    legacy fabric.

use std::collections::HashSet;

use aiconfigurator::config::{Candidate, ParallelSpec, ServingMode, WorkloadSpec};
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::by_name;
use aiconfigurator::ops::Op;
use aiconfigurator::perfdb::{LatencyOracle, PerfDatabase};
use aiconfigurator::search::{SearchSpace, TaskRunner};
use aiconfigurator::silicon::{comm, Silicon};
use aiconfigurator::topology::{fabric, FabricSpec, Placement};

// ---- 1. Pinned legacy equivalence -----------------------------------------

/// The seed's constants, frozen here on purpose.
const SEED_IB_GBS: f64 = 50.0;
const SEED_IB_LAT_US: f64 = 8.0;
const SEED_NVLINK_LAT_US: f64 = 2.0;
const SEED_COLL_EFF: f64 = 0.80;

fn seed_bw_lat(c: &ClusterSpec, gpus: u32) -> (f64, f64) {
    if gpus <= c.gpus_per_node {
        (c.gpu.nvlink_gbs * 1e3 * SEED_COLL_EFF, SEED_NVLINK_LAT_US)
    } else {
        (SEED_IB_GBS * 1e3 * SEED_COLL_EFF, SEED_IB_LAT_US)
    }
}

/// Verbatim copy of the seed's `silicon::comm::allreduce_us`.
fn seed_allreduce_us(c: &ClusterSpec, bytes: f64, gpus: u32) -> f64 {
    if gpus <= 1 {
        return 0.0;
    }
    let (bw, lat) = seed_bw_lat(c, gpus);
    let g = gpus as f64;
    let t = 2.0 * (g - 1.0) / g * bytes / bw + 2.0 * (g - 1.0) * lat;
    if gpus > c.gpus_per_node {
        t + 0.5 * seed_allreduce_us(c, bytes, c.gpus_per_node.min(gpus))
    } else {
        t
    }
}

fn seed_allgather_us(c: &ClusterSpec, bytes: f64, gpus: u32) -> f64 {
    if gpus <= 1 {
        return 0.0;
    }
    let (bw, lat) = seed_bw_lat(c, gpus);
    let g = gpus as f64;
    (g - 1.0) / g * bytes * g / bw + (g - 1.0) * lat
}

fn seed_alltoall_us(c: &ClusterSpec, bytes: f64, gpus: u32) -> f64 {
    if gpus <= 1 {
        return 0.0;
    }
    let (bw, lat) = seed_bw_lat(c, gpus);
    let g = gpus as f64;
    (g - 1.0) / g * bytes / bw + lat * (g - 1.0).sqrt() * 2.0
}

fn seed_p2p_us(c: &ClusterSpec, bytes: f64, cross: bool) -> f64 {
    let (bw, lat) = if cross {
        (SEED_IB_GBS * 1e3 * 0.9, SEED_IB_LAT_US)
    } else {
        (c.gpu.nvlink_gbs * 1e3 * 0.9, SEED_NVLINK_LAT_US)
    };
    lat + bytes / bw
}

#[test]
fn default_fabric_is_bit_for_bit_the_seed_topology() {
    for nodes in [1u32, 2, 4] {
        let c = ClusterSpec::new(h100_sxm(), 8, nodes);
        // The two constructors are the same cluster.
        let via_fabric = ClusterSpec::with_fabric(h100_sxm(), 8, nodes, FabricSpec::legacy(8));
        assert_eq!(c.fabric, via_fabric.fabric);
        for gpus in [1u32, 2, 4, 8, 16, 32] {
            if gpus > c.total_gpus() {
                continue;
            }
            for bytes in [512.0, 65536.0, 1e6, 3.3e7, 1e9] {
                assert_eq!(
                    comm::allreduce_us(&c, bytes, gpus),
                    seed_allreduce_us(&c, bytes, gpus),
                    "allreduce nodes={nodes} gpus={gpus} bytes={bytes}"
                );
                assert_eq!(
                    comm::allgather_us(&c, bytes, gpus),
                    seed_allgather_us(&c, bytes, gpus),
                    "allgather nodes={nodes} gpus={gpus} bytes={bytes}"
                );
                assert_eq!(
                    comm::alltoall_us(&c, bytes, gpus),
                    seed_alltoall_us(&c, bytes, gpus),
                    "alltoall nodes={nodes} gpus={gpus} bytes={bytes}"
                );
                assert_eq!(comm::p2p_us(&c, bytes, false), seed_p2p_us(&c, bytes, false));
                assert_eq!(comm::p2p_us(&c, bytes, true), seed_p2p_us(&c, bytes, true));
            }
        }
    }
}

#[test]
fn legacy_silicon_ignores_placement_spans() {
    // Ops constructed with any span/rails price identically on the
    // legacy fabric — the whole back-compat contract for candidates
    // built outside the placement enumerator.
    let c = ClusterSpec::new(h100_sxm(), 8, 2);
    let sil = Silicon::new(c, Framework::TrtLlm.profile());
    for (span, rails) in [(1u32, 1u32), (2, 1), (2, 4), (16, 8)] {
        let op = Op::AllReduce { bytes: 1e7, gpus: 16, span, rails, count: 1 };
        let base = Op::AllReduce { bytes: 1e7, gpus: 16, span: 1, rails: 1, count: 1 };
        assert_eq!(
            LatencyOracle::op_latency_us(&sil, &op),
            LatencyOracle::op_latency_us(&sil, &base)
        );
    }
}

#[test]
fn legacy_search_is_identical_through_both_constructors() {
    let model = by_name("qwen3-32b").unwrap();
    let a = ClusterSpec::new(h100_sxm(), 8, 2);
    let b = ClusterSpec::with_fabric(h100_sxm(), 8, 2, FabricSpec::legacy(8));
    let wl = WorkloadSpec::new("qwen3-32b", 2048, 256, 2000.0, 10.0);
    let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
    space.batch = vec![8, 32];
    space.max_x = 4;
    space.max_y = 4;
    let run = |c: &ClusterSpec| {
        let sil = Silicon::new(*c, Framework::TrtLlm.profile());
        TaskRunner::new(&model, c, space.clone(), wl.clone()).run(&sil)
    };
    let ra = run(&a);
    let rb = run(&b);
    assert_eq!(ra.evaluated.len(), rb.evaluated.len());
    for (x, y) in ra.evaluated.iter().zip(&rb.evaluated) {
        assert_eq!(x.cand, y.cand);
        assert_eq!(x.est, y.est);
    }
    // Every candidate is packed — the placement axis is invisible on
    // the legacy fabric.
    for e in &ra.evaluated {
        let eng = match &e.cand {
            Candidate::Aggregated { engine, .. } => engine,
            Candidate::Disaggregated { decode, .. } => decode,
        };
        assert_eq!(eng.placement, Placement::packed());
    }
}

// ---- 2. Acceptance: placements priced, reported, emitted ------------------

#[test]
fn two_node_fabric_search_prices_distinct_placements() {
    let model = by_name("qwen3-32b").unwrap();
    let cluster = ClusterSpec::with_fabric(h100_sxm(), 8, 2, fabric::hgx_h100());
    let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
    let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
    space.tp = vec![8];
    space.pp = vec![2];
    space.batch = vec![16];
    space.modes = vec![ServingMode::Aggregated];
    let wl = WorkloadSpec::new("qwen3-32b", 2048, 256, f64::INFINITY, 0.0);
    let report = TaskRunner::new(&model, &cluster, space, wl.clone()).run(&sil);

    // The same (tp=8, pp=2) shape appears under several rank layouts…
    let shape = ParallelSpec { tp: 8, pp: 2, ep: 1, dp: 1 };
    let placed: Vec<_> = report
        .evaluated
        .iter()
        .filter_map(|e| match &e.cand {
            Candidate::Aggregated { engine, .. } if engine.parallel == shape => {
                Some((engine.placement, e.est.tpot_ms, e.est.ttft_ms))
            }
            _ => None,
        })
        .collect();
    let layouts: HashSet<Placement> = placed.iter().map(|(pl, _, _)| *pl).collect();
    assert!(layouts.len() >= 2, "placements priced: {layouts:?}");
    // …with genuinely different prices.
    let prices: HashSet<u64> = placed.iter().map(|(_, tpot, _)| tpot.to_bits()).collect();
    assert!(prices.len() >= 2, "all placements priced identically: {placed:?}");

    // The chosen placement is visible in the report: candidate labels
    // name the non-packed layouts.
    assert!(
        report.evaluated.iter().any(|e| e.cand.label().contains("tp2dom")
            || e.cand.label().contains("-r4")),
        "no placement label in the report"
    );

    // …and rides into the emitted launch bundle.
    let spanned = report
        .evaluated
        .iter()
        .find(|e| matches!(&e.cand, Candidate::Aggregated { engine, .. }
            if engine.placement != Placement::packed()))
        .expect("a non-packed candidate");
    let bundle = aiconfigurator::generator::generate(&spanned.cand, model.name, &wl);
    let readme = bundle.get("README.launch.md").unwrap();
    let eng = match &spanned.cand {
        Candidate::Aggregated { engine, .. } => engine,
        _ => unreachable!(),
    };
    assert!(
        readme.contains(&format!("Placement: {}", eng.placement.label())),
        "launch README missing placement: {readme}"
    );
}

#[test]
fn disagg_bundle_carries_pool_placements() {
    let model = by_name("qwen3-32b").unwrap();
    let cluster = ClusterSpec::with_fabric(h100_sxm(), 8, 2, fabric::hgx_h100());
    let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
    let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
    space.batch = vec![16];
    space.max_x = 4;
    space.max_y = 4;
    space.modes = vec![ServingMode::Disaggregated];
    let wl = WorkloadSpec::new("qwen3-32b", 2048, 256, f64::INFINITY, 0.0);
    let report = TaskRunner::new(&model, &cluster, space, wl.clone()).run(&sil);
    let best = report
        .evaluated
        .iter()
        .find(|e| matches!(e.cand, Candidate::Disaggregated { .. }))
        .expect("a disaggregated composite");
    let bundle = aiconfigurator::generator::generate(&best.cand, model.name, &wl);
    let yaml = bundle.get("dynamo_disagg.yaml").unwrap();
    assert!(yaml.contains("placement: "), "dynamo spec missing placement: {yaml}");
}

// ---- 3. Database placement sensitivity ------------------------------------

#[test]
fn database_scales_packed_baseline_by_placement_factor() {
    let model = by_name("llama3.1-8b").unwrap();
    let tiered = ClusterSpec::with_fabric(h100_sxm(), 8, 2, fabric::hgx_h100());
    let sil = Silicon::new(tiered, Framework::TrtLlm.profile());
    let db = PerfDatabase::build(&sil, &model, aiconfigurator::models::Dtype::Fp8, 0xA1C0);
    let packed = Op::AllReduce { bytes: 1e7, gpus: 8, span: 1, rails: 1, count: 1 };
    let spanned = Op::AllReduce { bytes: 1e7, gpus: 8, span: 2, rails: 1, count: 1 };
    let base = db.op_latency_us(&packed);
    let placed = db.op_latency_us(&spanned);
    assert!(placed > base * 1.2, "db must price the spanning layout dearer: {base} vs {placed}");
    // The scaling matches the analytic factor exactly.
    let factor =
        aiconfigurator::topology::collective::placement_factor(&tiered, &spanned);
    assert!((placed / base - factor).abs() < 1e-9, "{placed}/{base} != {factor}");

    // Legacy databases stay placement-blind.
    let legacy = ClusterSpec::new(h100_sxm(), 8, 2);
    let lsil = Silicon::new(legacy, Framework::TrtLlm.profile());
    let ldb = PerfDatabase::build(&lsil, &model, aiconfigurator::models::Dtype::Fp8, 0xA1C0);
    assert_eq!(ldb.op_latency_us(&packed), ldb.op_latency_us(&spanned));
}

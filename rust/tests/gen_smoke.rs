//! Generator smoke gate (CI): the `generate` path must produce a
//! non-empty launch bundle for ALL three backends on one example
//! workload, and every emitted launch file must carry the
//! backend-resolved flag values. Guards the `Backend` trait dispatch
//! against a backend silently falling out of the registry and against
//! emission drifting from the resolver.

use aiconfigurator::config::{Candidate, EngineConfig, ParallelSpec, WorkloadSpec};
use aiconfigurator::frameworks::Framework;
use aiconfigurator::generator;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype};

#[test]
fn every_backend_emits_resolved_flags() {
    let model = by_name("qwen3-32b").unwrap();
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let wl = WorkloadSpec::new("qwen3-32b", 4000, 500, 1200.0, 60.0);
    let parallel = ParallelSpec::tp(2);
    let batch = 16;

    for fw in Framework::all() {
        let be = fw.backend();
        let flags = be.resolve_flags(&model, &cluster, &wl, &parallel, batch, Dtype::Fp8);
        let eng = EngineConfig {
            framework: fw,
            parallel,
            batch,
            weight_dtype: Dtype::Fp8,
            kv_dtype: Dtype::Fp8,
            flags,
            placement: aiconfigurator::topology::Placement::packed(),
        };
        let bundle = generator::generate(
            &Candidate::Aggregated { engine: eng, replicas: 2 },
            "org/example-model",
            &wl,
        );
        assert!(!bundle.files.is_empty(), "{fw:?}: empty launch bundle");
        let sh = bundle
            .get("launch_server.sh")
            .unwrap_or_else(|| panic!("{fw:?}: bundle lacks launch_server.sh"));
        let kv = format!("{:.2}", flags.kv_frac);
        let mnt = flags.max_num_tokens.to_string();
        assert!(sh.contains(&kv), "{fw:?}: launch script omits resolved kv_frac {kv}:\n{sh}");
        assert!(sh.contains(&mnt), "{fw:?}: launch script omits resolved max_num_tokens {mnt}:\n{sh}");
        assert!(sh.contains("org/example-model"), "{fw:?}: launch script omits model id");
        // Every file in the bundle is non-empty.
        for (name, content) in &bundle.files {
            assert!(!content.trim().is_empty(), "{fw:?}: {name} is empty");
        }
    }
}

#[test]
fn disagg_bundle_resolved_flags_per_pool() {
    // Disaggregated composites resolve flags per pool (prefill batch 1,
    // decode batch 64) and each pool's launch file carries its own.
    let model = by_name("qwen3-32b").unwrap();
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let wl = WorkloadSpec::new("qwen3-32b", 4000, 500, 1200.0, 60.0);
    let be = Framework::TrtLlm.backend();
    let mk = |p: ParallelSpec, b: u32| EngineConfig {
        framework: Framework::TrtLlm,
        parallel: p,
        batch: b,
        weight_dtype: Dtype::Fp8,
        kv_dtype: Dtype::Fp8,
        flags: be.resolve_flags(&model, &cluster, &wl, &p, b, Dtype::Fp8),
        placement: aiconfigurator::topology::Placement::packed(),
    };
    let prefill = mk(ParallelSpec::tp(1), 1);
    let decode = mk(ParallelSpec::tp(2), 64);
    let bundle = generator::generate(
        &Candidate::Disaggregated { prefill, decode, x: 4, y: 2 },
        "org/example-model",
        &wl,
    );
    let pre = bundle.get("launch_prefill.sh").unwrap();
    let dec = bundle.get("launch_decode.sh").unwrap();
    assert!(pre.contains(&format!("{:.2}", prefill.flags.kv_frac)));
    assert!(dec.contains(&format!("{:.2}", decode.flags.kv_frac)));
    // TP1 prefill holds heavier weights per GPU than TP2 decode: its
    // resolved KV fraction must be no larger.
    assert!(prefill.flags.kv_frac <= decode.flags.kv_frac);
    assert!(bundle.get("dynamo_disagg.yaml").is_some());
}

//! PJRT runtime integration: the AOT-compiled Pallas kernels must agree
//! with the native Rust implementations on identical inputs.
//!
//! Requires `make artifacts`; every test skips gracefully when the
//! artifacts are absent (e.g. a cargo-only run).

use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::ops::Op;
use aiconfigurator::perfdb::tables::{query_for, GRID_LEN};
use aiconfigurator::perfdb::{LatencyOracle, PerfDatabase};
use aiconfigurator::runtime::{PjrtOracle, PjrtService, MOE_EXPERTS};
use aiconfigurator::silicon::Silicon;
use aiconfigurator::util::rng::Rng;

fn artifacts() -> Option<&'static std::path::Path> {
    let p = std::path::Path::new("artifacts");
    if p.join("interp.hlo.txt").exists() && p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn db() -> (Silicon, PerfDatabase) {
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let silicon = Silicon::new(cluster, Framework::TrtLlm.profile());
    let model = by_name("qwen3-235b").unwrap();
    let db = PerfDatabase::build(&silicon, &model, Dtype::Fp8, 0xBEEF);
    (silicon, db)
}

fn random_op(rng: &mut Rng) -> Op {
    match rng.below(7) {
        0 => Op::Gemm {
            m: 1 + rng.below(200_000),
            n: 64 + rng.below(100_000),
            k: 64 + rng.below(30_000),
            dtype: [Dtype::Fp16, Dtype::Fp8, Dtype::Int8, Dtype::Int4][rng.below(4) as usize],
            count: 1,
        },
        1 => Op::AttnPrefill {
            q_tokens: 1 + rng.below(16_000),
            kv_len: 16 + rng.below(100_000),
            heads: 1 + rng.below(128),
            head_dim: 128,
            causal_frac: 1.0,
            count: 1,
        },
        2 => Op::AttnDecode {
            batch: 1 + rng.below(512),
            kv_len: 16 + rng.below(100_000),
            heads: 1 + rng.below(128),
            head_dim: 128,
            kv_token_bytes: 256.0,
            count: 1,
        },
        3 => Op::MoeGemm {
            tokens: 1 + rng.below(100_000),
            experts: 1 + rng.below(256),
            inter: 1536,
            hidden: 4096,
            dtype: Dtype::Fp8,
            imbalance: 1.0 + rng.f64() * 6.0,
            count: 1,
        },
        4 => Op::AllReduce { bytes: 1e3 + rng.f64() * 1e8, gpus: 2 + rng.below(62) as u32, span: 1, rails: 1, count: 1 },
        5 => Op::AllToAll { bytes: 1e3 + rng.f64() * 1e8, gpus: 2 + rng.below(62) as u32, span: 1, rails: 1, count: 1 },
        _ => Op::P2p { bytes: 1e3 + rng.f64() * 1e8, cross_node: rng.below(2) == 1, count: 1 },
    }
}

#[test]
fn pjrt_interp_matches_native_on_random_queries() {
    let Some(dir) = artifacts() else { return };
    let (_, db) = db();
    let svc = PjrtService::start(dir, db.grids().to_vec()).unwrap();
    let oracle = PjrtOracle { svc: &svc, db: &db };
    let mut rng = Rng::new(99);
    for i in 0..200 {
        let op = random_op(&mut rng);
        if query_for(&op).is_none() {
            continue;
        }
        let native = db.op_latency_us(&op);
        let pjrt = oracle.op_latency_us(&op);
        // f32 kernel vs f64 native: allow small relative drift.
        let err = (native - pjrt).abs() / native.max(1e-9);
        assert!(err < 1e-3, "case {i} {op:?}: native {native} pjrt {pjrt}");
    }
}

#[test]
fn pjrt_step_latency_batches_correctly() {
    let Some(dir) = artifacts() else { return };
    let (silicon, db) = db();
    let svc = PjrtService::start(dir, db.grids().to_vec()).unwrap();
    let oracle = PjrtOracle { svc: &svc, db: &db };
    let model = by_name("qwen3-235b").unwrap();
    let eng = aiconfigurator::config::EngineConfig {
        framework: Framework::TrtLlm,
        parallel: aiconfigurator::config::ParallelSpec { tp: 4, pp: 1, ep: 4, dp: 1 },
        batch: 16,
        weight_dtype: Dtype::Fp8,
        kv_dtype: Dtype::Fp8,
        flags: aiconfigurator::config::RuntimeFlags::defaults_for(Framework::TrtLlm),
        placement: aiconfigurator::topology::Placement::packed(),
    };
    let shape = aiconfigurator::ops::StepShape {
        ctx_reqs: 1,
        ctx_q: 2048,
        ctx_kv: 2048,
        gen_reqs: 15,
        gen_kv: 3000,
    };
    let ops = aiconfigurator::ops::decompose(&model, &silicon.cluster, &eng, &shape, 1.4);
    let native = db.step_latency_us(&ops);
    let pjrt = oracle.step_latency_us(&ops);
    assert!(
        (native - pjrt).abs() / native < 1e-3,
        "native {native} vs pjrt {pjrt}"
    );
}

#[test]
fn pjrt_chunking_beyond_query_batch() {
    let Some(dir) = artifacts() else { return };
    let (_, db) = db();
    let svc = PjrtService::start(dir, db.grids().to_vec()).unwrap();
    // 20k queries → 3 chunks of 8192 with padding.
    let n = 20_000;
    let mut rng = Rng::new(5);
    let tids: Vec<i32> = (0..n).map(|_| rng.below(14) as i32).collect();
    let coords: Vec<f32> = (0..n * 3).map(|_| (rng.f64() * 31.0) as f32).collect();
    let out = svc.interp(&tids, &coords).unwrap();
    assert_eq!(out.len(), n);
    // Spot-check a few against native trilinear.
    for i in [0usize, 4095, 8192, 19_999] {
        let native = aiconfigurator::perfdb::query::trilinear(
            db.grids(),
            tids[i] as usize,
            coords[i * 3] as f64,
            coords[i * 3 + 1] as f64,
            coords[i * 3 + 2] as f64,
        );
        assert!(
            (out[i] as f64 - native).abs() / native.max(1e-9) < 1e-3,
            "i={i}: {} vs {native}",
            out[i]
        );
    }
}

#[test]
fn pjrt_moe_matches_native_sampler_statistics() {
    let Some(dir) = artifacts() else { return };
    let svc = PjrtService::start(dir, vec![0f32; GRID_LEN]).unwrap();
    let mut rng = Rng::new(11);
    let s = 8;
    let u: Vec<f32> = (0..s * MOE_EXPERTS).map(|_| rng.f64_open() as f32).collect();
    let alpha: Vec<f32> = (0..s).map(|i| 0.1 + 0.18 * i as f32).collect();
    let params: Vec<f32> = (0..s).flat_map(|_| [1.0f32, 100.0, 4096.0]).collect();
    let (loads, imb) = svc.moe(&u, &alpha, &params).unwrap();
    for i in 0..s {
        let sum: f32 = loads[i * MOE_EXPERTS..(i + 1) * MOE_EXPERTS].iter().sum();
        assert!((sum - 4096.0).abs() < 1.0, "scenario {i} sum {sum}");
        assert!(imb[i] >= 1.0);
    }
    // Imbalance rises with alpha overall (allow local noise).
    assert!(imb[s - 1] > imb[0], "{imb:?}");
}

#[test]
fn pjrt_service_concurrent_clients() {
    let Some(dir) = artifacts() else { return };
    let (_, db) = db();
    let svc = std::sync::Arc::new(PjrtService::start(dir, db.grids().to_vec()).unwrap());
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t);
            for _ in 0..20 {
                let n = 16;
                let tids: Vec<i32> = (0..n).map(|_| rng.below(14) as i32).collect();
                let coords: Vec<f32> = (0..n * 3).map(|_| (rng.f64() * 15.0) as f32).collect();
                let out = svc.interp(&tids, &coords).unwrap();
                assert_eq!(out.len(), n);
                assert!(out.iter().all(|v| v.is_finite()));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn manifest_contract_enforced() {
    let Some(dir) = artifacts() else { return };
    let m = aiconfigurator::runtime::Manifest::load(&dir.join("manifest.json")).unwrap();
    m.check_contract().unwrap();
}

//! Fleet-replay integration: the degenerate-equivalence pin (a fleet of
//! one replica with no lag/failures/contention reproduces the single
//! engine simulator bit-for-bit), seeded determinism, and graceful
//! degradation under failure injection.

use aiconfigurator::config::{Candidate, EngineConfig, ParallelSpec, RuntimeFlags, WorkloadSpec};
use aiconfigurator::fleetsim::{self, FleetConfig, FleetLeg};
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype, ModelArch};
use aiconfigurator::perfmodel::PerfEstimate;
use aiconfigurator::planner::{DeploymentPlan, PlanSpec, TrafficModel, WindowPlan};
use aiconfigurator::silicon::Silicon;
use aiconfigurator::simulator::aggregated::AggregatedSim;
use aiconfigurator::simulator::SimConfig;
use aiconfigurator::workload::Request;

const WINDOW_H: f64 = 0.01; // 36 s windows keep the traces small

fn engine() -> EngineConfig {
    EngineConfig {
        framework: Framework::TrtLlm,
        parallel: ParallelSpec::tp(2),
        batch: 16,
        weight_dtype: Dtype::Fp8,
        kv_dtype: Dtype::Fp8,
        flags: RuntimeFlags::defaults_for(Framework::TrtLlm),
        placement: aiconfigurator::topology::Placement::packed(),
    }
}

/// A hand-built single-segment plan: `windows` windows of the same TP2
/// unit on h100 at `replicas` replicas each. Replay only reads
/// gpu/cand/replicas/window-span per window.
fn flat_plan(replicas: u32, windows: usize) -> DeploymentPlan {
    let cand = Candidate::Aggregated { engine: engine(), replicas: 1 };
    let est =
        PerfEstimate { ttft_ms: 100.0, tpot_ms: 50.0, speed: 20.0, thru_per_gpu: 1.0, concurrency: 16 };
    let wins = (0..windows)
        .map(|i| WindowPlan {
            index: i,
            t_start_h: i as f64 * WINDOW_H,
            t_end_h: (i + 1) as f64 * WINDOW_H,
            demand_qps: 2.0,
            gpu: "h100".into(),
            cand: cand.clone(),
            replicas,
            gpus: (replicas * 2) as u64,
            capacity_qps: replicas as f64 * 50.0,
            est,
            cost_usd: 1.0,
        })
        .collect();
    DeploymentPlan {
        windows: wins,
        total_cost_usd: 1.0,
        best_homogeneous: None,
        static_peak_cost_usd: 2.0,
        options_considered: 1,
        options_pruned: 0,
    }
}

fn fixture(windows: usize) -> (ModelArch, ClusterSpec, Silicon, PlanSpec, Vec<Request>) {
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let sil = Silicon::new(cluster, Framework::TrtLlm.profile());
    let model = by_name("llama3.1-8b").unwrap();
    let wl = WorkloadSpec::new("llama3.1-8b", 256, 32, 5000.0, 2.0);
    let spec = PlanSpec::new(
        wl.clone(),
        TrafficModel::Ramp { start_qps: 2.0, end_qps: 2.0 },
        windows,
        WINDOW_H,
    );
    let trace = spec.traffic.trace(windows, WINDOW_H, &wl, 0.0, 123);
    assert!(!trace.is_empty(), "fixture trace must carry requests");
    (model, cluster, sil, spec, trace)
}

fn benign_cfg() -> FleetConfig {
    FleetConfig {
        seed: 5,
        scale_lag_s: 0.0,
        failure_rate_per_replica_h: 0.0,
        restart_s: 120.0,
        sim: SimConfig::default(),
    }
}

/// The tentpole composition guarantee: one replica, zero lag, zero
/// failures, no contention (aggregated unit) must reproduce the
/// single-replica `AggregatedSim` run over the identical trace with
/// the identical `SimConfig` *exactly* — same per-request latencies,
/// same completion count, same makespan.
#[test]
fn degenerate_fleet_reproduces_the_engine_simulator_exactly() {
    let (model, cluster, sil, spec, trace) = fixture(2);
    let plan = flat_plan(1, 2);
    let cfg = benign_cfg();
    let legs = [FleetLeg { name: "h100".into(), cluster, silicon: &sil }];
    let rep = fleetsim::replay(&model, &spec, &plan, &legs, &trace, &cfg).unwrap();

    let direct = AggregatedSim::new(&sil, &model, &cluster, engine(), cfg.sim).run(&trace);

    assert_eq!(rep.offered, trace.len());
    assert_eq!(rep.completed, direct.completed, "completion counts must match exactly");
    assert_eq!(rep.makespan_ms, direct.makespan_ms, "makespan must match bit-for-bit");

    let sorted = |mut v: Vec<f64>| {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    };
    let fleet_ttfts = sorted(rep.requests.iter().filter_map(|r| r.ttft_ms).collect());
    let fleet_tpots = sorted(rep.requests.iter().filter_map(|r| r.tpot_ms).collect());
    assert_eq!(fleet_ttfts, sorted(direct.ttft_ms.clone()), "TTFT streams must be identical");
    assert_eq!(fleet_tpots, sorted(direct.tpot_ms.clone()), "TPOT streams must be identical");
}

/// Satellite: the whole replay is deterministic per seed, and the
/// engine jitter stream actually responds to the seed.
#[test]
fn replay_is_deterministic_per_seed() {
    let (model, cluster, sil, spec, trace) = fixture(2);
    let plan = flat_plan(2, 2);
    let cfg = benign_cfg();
    let legs = [FleetLeg { name: "h100".into(), cluster, silicon: &sil }];
    let a = fleetsim::replay(&model, &spec, &plan, &legs, &trace, &cfg).unwrap();
    let b = fleetsim::replay(&model, &spec, &plan, &legs, &trace, &cfg).unwrap();
    assert_eq!(a.to_json().to_string(), b.to_json().to_string(), "same seed, same report");

    let mut other = cfg;
    other.sim.seed ^= 0xBEEF;
    let c = fleetsim::replay(&model, &spec, &plan, &legs, &trace, &other).unwrap();
    let ttfts = |r: &fleetsim::ValidationReport| -> Vec<f64> {
        r.requests.iter().filter_map(|q| q.ttft_ms).collect()
    };
    assert_ne!(ttfts(&a), ttfts(&c), "a different engine seed must move the jitter stream");
}

/// Satellite: failure injection degrades attainment without panicking,
/// and every loss is cause-typed.
#[test]
fn failure_injection_degrades_gracefully() {
    let (model, cluster, sil, spec, trace) = fixture(4);
    let plan = flat_plan(2, 4);
    let legs = [FleetLeg { name: "h100".into(), cluster, silicon: &sil }];

    let run = |rate: f64| {
        let mut cfg = benign_cfg();
        cfg.failure_rate_per_replica_h = rate;
        cfg.restart_s = 30.0;
        fleetsim::replay(&model, &spec, &plan, &legs, &trace, &cfg).unwrap()
    };
    let clean = run(0.0);
    let shaky = run(100.0);
    let broken = run(2000.0);

    assert_eq!(clean.failures, 0);
    assert!(shaky.failures > 0, "100 failures/replica-h over 2.4 min must fire");
    assert!(broken.failures > shaky.failures);

    // Monotone against the clean baseline (independent failure draws
    // mean shaky-vs-broken ordering is only expected, not guaranteed).
    assert!(shaky.achieved_attainment <= clean.achieved_attainment + 1e-12);
    assert!(broken.achieved_attainment < clean.achieved_attainment);

    // Every injected miss is attributed: failure-typed misses appear,
    // counts stay consistent, and the report renders.
    assert!(broken.misses.failure > 0, "failure-typed misses must be attributed");
    assert_eq!(broken.offered, trace.len());
    assert_eq!(
        broken.completed + broken.preempted + broken.dropped,
        broken.offered,
        "every request is completed, preempted, or dropped"
    );
    assert!(broken.optimism_gap >= clean.optimism_gap);
    assert!(broken.render().contains("optimism gap"));
}

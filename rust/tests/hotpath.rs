//! Hot-path parity pins for the raw-speed pass: the batched oracle
//! entry point ([`LatencyOracle::latency_batch`], slab-walk
//! interpolation in the PerfDatabase) and the memo/thread-local search
//! plumbing ([`TaskRunner::run_cached`]) must be **bit-for-bit**
//! indistinguishable from the scalar per-op path they replaced.
//!
//! Two families of pins:
//! 1. `latency_batch == map(op_latency_us)` to the last mantissa bit,
//!    across every op kind × every oracle tier (analytic PerfDatabase
//!    on legacy and tiered fabrics, CalibratedDb, MemoOracle cold and
//!    warm, LocalMemo, Silicon ground truth);
//! 2. pinned searches (qwen3-32b on H100, and on a gb200-nvl72 tiered
//!    fabric) produce the same candidate labels, in the same order,
//!    with bit-identical estimates whether priced through `run` (plain
//!    oracle) or `run_cached` (shared memo + per-worker LocalMemo).

use aiconfigurator::config::{EngineConfig, ParallelSpec, RuntimeFlags, WorkloadSpec};
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{gb200_nvl72, h100_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::ops::{decompose, Op, StepShape};
use aiconfigurator::perfdb::tables::TableId;
use aiconfigurator::perfdb::{calibrate, measure, CalibratedDb, LatencyOracle, MemoOracle, PerfDatabase};
use aiconfigurator::search::{RunOptions, SearchSpace, TaskRunner};
use aiconfigurator::silicon::Silicon;
use aiconfigurator::topology::{fabric, Placement};

fn eng(fw: Framework, tp: u32, pp: u32, ep: u32, placement: Placement) -> EngineConfig {
    EngineConfig {
        framework: fw,
        parallel: ParallelSpec { tp, pp, ep, dp: 1 },
        batch: 16,
        weight_dtype: Dtype::Fp8,
        kv_dtype: Dtype::Fp8,
        flags: RuntimeFlags::defaults_for(fw),
        placement,
    }
}

/// An op list that exercises every [`Op`] kind: dense + MoE models,
/// prefill + decode + mixed steps, TP/PP/EP collectives, packed and
/// spanned placements.
fn all_kind_ops(cluster: &ClusterSpec) -> Vec<Op> {
    let dense = by_name("qwen3-32b").unwrap();
    let moe = by_name("qwen3-235b").unwrap();
    let spanned = Placement { tp_span: 2, ep_span: 2, interleave_pp: false, rails: 4 };
    let mut ops = Vec::new();
    // Dense, TP4 PP2 packed: Gemm, AttnPrefill, AllReduce, AllGather,
    // P2p, Elementwise.
    ops.extend(decompose(
        &dense,
        cluster,
        &eng(Framework::TrtLlm, 4, 2, 1, Placement::packed()),
        &StepShape::prefill(2, 2048, 2048),
        1.0,
    ));
    // Dense decode, mixed step: AttnDecode joins.
    ops.extend(decompose(
        &dense,
        cluster,
        &eng(Framework::Vllm, 2, 1, 1, Placement::packed()),
        &StepShape { ctx_reqs: 1, ctx_q: 512, ctx_kv: 512, gen_reqs: 32, gen_kv: 2048 },
        1.0,
    ));
    // MoE, TP2 EP8 spanned: MoeGemm, AllToAll, placed collectives.
    ops.extend(decompose(
        &moe,
        cluster,
        &eng(Framework::Sglang, 2, 1, 8, spanned),
        &StepShape::decode(64, 4096),
        1.25,
    ));
    let classes: std::collections::BTreeSet<&str> = ops.iter().map(|o| o.class()).collect();
    assert_eq!(
        classes.len(),
        9,
        "op list must cover all 9 op kinds, got {classes:?}"
    );
    ops
}

/// The pin itself: batch answers equal scalar answers to the bit, and
/// the step reduction equals the batch-then-weighted-sum it documents.
fn assert_batch_parity(name: &str, oracle: &dyn LatencyOracle, ops: &[Op]) {
    assert!(oracle.latency_batch(&[]).is_empty(), "{name}: empty batch");
    let per: Vec<f64> = ops.iter().map(|o| oracle.op_latency_us(o)).collect();
    let batch = oracle.latency_batch(ops);
    assert_eq!(per.len(), batch.len(), "{name}: length");
    for (i, (p, b)) in per.iter().zip(&batch).enumerate() {
        assert_eq!(
            p.to_bits(),
            b.to_bits(),
            "{name}: op {i} ({}) diverged: per-op {p} vs batched {b}",
            ops[i].class()
        );
    }
    let want_step: f64 = batch.iter().zip(ops).map(|(l, o)| l * o.count() as f64).sum();
    let step = oracle.step_latency_us(ops);
    assert_eq!(
        want_step.to_bits(),
        step.to_bits(),
        "{name}: step_latency_us is not the batch-weighted sum"
    );
}

#[test]
fn latency_batch_matches_per_op_bit_for_bit_across_oracle_tiers() {
    // Legacy flat fabric: slab interpolation + SoL fallbacks.
    let legacy = ClusterSpec::new(h100_sxm(), 8, 2);
    let sil = Silicon::new(legacy, Framework::TrtLlm.profile());
    let model = by_name("qwen3-32b").unwrap();
    let db = PerfDatabase::build(&sil, &model, Dtype::Fp8, 0xA1C0);
    let ops = all_kind_ops(&legacy);

    assert_batch_parity("silicon", &sil, &ops);
    assert_batch_parity("perfdb/legacy", &db, &ops);

    // Tiered fabric: the placement-factor table path on every placed
    // collective (gb200-nvl72 has a 72-GPU NVLink domain).
    let tiered = ClusterSpec::with_fabric(gb200_nvl72(), 4, 18, fabric::gb200_nvl72());
    let tsil = Silicon::new(tiered, Framework::TrtLlm.profile());
    let tdb = PerfDatabase::build(&tsil, &model, Dtype::Fp8, 0xA1C0);
    assert_batch_parity("perfdb/gb200-nvl72", &tdb, &all_kind_ops(&tiered));

    // Calibrated tier: measured-cell snap + correction-scaled slabs.
    let sets = measure::synthesize_with(&sil, &model, Dtype::Fp8, 17, 32, &|_| (1.3, 0.0), 0.02);
    let gemm_sets: Vec<_> = sets
        .into_iter()
        .filter(|s| matches!(s.table, TableId::GemmFp16 | TableId::GemmFp8))
        .collect();
    let art = calibrate::fit(&db, &gemm_sets).unwrap();
    let cal = CalibratedDb::compose(db.clone(), &art).unwrap();
    assert_batch_parity("calibrated", &cal, &ops);

    // Memo tier, cold (every query a miss) and warm (every query a
    // shared-store hit), plus the thread-local front the search
    // workers price through.
    let memo = MemoOracle::new(&db);
    assert_batch_parity("memo/cold", &memo, &ops);
    let (hits, misses) = memo.stats();
    assert!(misses > 0, "cold memo must record misses");
    assert_batch_parity("memo/warm", &memo, &ops);
    let (hits2, _) = memo.stats();
    assert!(hits2 > hits, "warm pass must hit the shared store");

    let lm = memo.local();
    assert_batch_parity("memo/local", &lm, &ops);
    lm.merge();
}

/// Run the pinned search both ways and pin labels, order, and bits.
fn assert_search_parity(model_name: &str, cluster: &ClusterSpec, seed: u64) {
    let model = by_name(model_name).unwrap();
    let sil = Silicon::new(*cluster, Framework::TrtLlm.profile());
    let db = PerfDatabase::build(&sil, &model, Dtype::Fp8, seed);
    let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
    space.batch = vec![8, 32];
    space.max_x = 4;
    space.max_y = 4;
    let wl = WorkloadSpec::new(model_name, 2048, 256, 2000.0, 20.0);
    let runner = TaskRunner::new(&model, cluster, space, wl);

    for opts in [RunOptions::default(), RunOptions { prune: true }] {
        let plain = runner.run_with(&db, &opts);
        let memo = MemoOracle::new(&db);
        let cold = runner.run_cached(&memo, &opts);
        let warm = runner.run_cached(&memo, &opts);
        let (hits, _) = memo.stats();
        assert!(hits > 0, "second cached run must hit the memo");
        assert!(!plain.evaluated.is_empty(), "pinned search evaluates candidates");
        assert_eq!(plain.pruned, cold.pruned, "prune={}", opts.prune);
        for cached in [&cold, &warm] {
            assert_eq!(plain.evaluated.len(), cached.evaluated.len());
            for (a, b) in plain.evaluated.iter().zip(&cached.evaluated) {
                assert_eq!(a.cand.label(), b.cand.label(), "labels in the same order");
                assert_eq!(a.cand, b.cand);
                assert_eq!(a.est.speed.to_bits(), b.est.speed.to_bits());
                assert_eq!(a.est.thru_per_gpu.to_bits(), b.est.thru_per_gpu.to_bits());
                assert_eq!(a.est.ttft_ms.to_bits(), b.est.ttft_ms.to_bits());
                assert_eq!(a.est.tpot_ms.to_bits(), b.est.tpot_ms.to_bits());
            }
        }
    }
}

#[test]
fn pinned_qwen3_32b_h100_search_is_memo_invariant() {
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    assert_search_parity("qwen3-32b", &cluster, 0xA1C0);
}

#[test]
fn pinned_gb200_nvl72_search_is_memo_invariant() {
    let cluster = ClusterSpec::with_fabric(gb200_nvl72(), 4, 18, fabric::gb200_nvl72());
    assert_search_parity("qwen3-32b", &cluster, 0xA1C0);
}

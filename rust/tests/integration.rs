//! Cross-module integration: the full search pipeline over the real
//! database, database-vs-silicon oracle agreement, and analytical-model
//! vs simulator consistency on matched configurations.

use aiconfigurator::config::{Candidate, ServingMode, WorkloadSpec};
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{h100_sxm, h200_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::pareto;
use aiconfigurator::perfdb::{LatencyOracle, PerfDatabase};
use aiconfigurator::perfmodel;
use aiconfigurator::search::{SearchSpace, TaskRunner};
use aiconfigurator::silicon::Silicon;
use aiconfigurator::simulator::{aggregated::AggregatedSim, SimConfig};
use aiconfigurator::workload::closed_loop;

fn fixture(model: &str, fw: Framework) -> (Silicon, aiconfigurator::models::ModelArch, PerfDatabase)
{
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let silicon = Silicon::new(cluster, fw.profile());
    let m = by_name(model).unwrap();
    let db = PerfDatabase::build(&silicon, &m, Dtype::Fp8, 0xFEED);
    (silicon, m, db)
}

#[test]
fn full_pipeline_dense_model() {
    let (silicon, model, db) = fixture("qwen3-32b", Framework::TrtLlm);
    let wl = WorkloadSpec::new("qwen3-32b", 2048, 256, 1500.0, 20.0);
    let space = SearchSpace::default_for(&model, Framework::TrtLlm);
    let report = TaskRunner::new(&model, &silicon.cluster, space, wl.clone()).run(&db);
    assert!(report.configs_priced >= 20);
    let analysis = pareto::analyze(&report.evaluated, &wl.sla);
    assert!(!analysis.feasible.is_empty(), "SLA should be satisfiable");
    let best = analysis.best().unwrap();
    assert!(best.est.meets(&wl.sla));
    // Frontier members are all feasible and mutually non-dominated.
    for &i in &analysis.frontier {
        assert!(analysis.feasible[i].est.meets(&wl.sla));
    }
}

#[test]
fn db_oracle_tracks_silicon_within_tolerance() {
    // The product-path oracle (noisy profiled grids + interpolation)
    // must track the true silicon on step latencies of realistic shapes.
    let (silicon, model, db) = fixture("qwen3-235b", Framework::TrtLlm);
    let eng = aiconfigurator::config::EngineConfig {
        framework: Framework::TrtLlm,
        parallel: aiconfigurator::config::ParallelSpec { tp: 4, pp: 1, ep: 4, dp: 1 },
        batch: 32,
        weight_dtype: Dtype::Fp8,
        kv_dtype: Dtype::Fp8,
        flags: aiconfigurator::config::RuntimeFlags::defaults_for(Framework::TrtLlm),
        placement: aiconfigurator::topology::Placement::packed(),
    };
    for shape in [
        aiconfigurator::ops::StepShape::prefill(1, 4096, 4096),
        aiconfigurator::ops::StepShape::decode(32, 3000),
        aiconfigurator::ops::StepShape { ctx_reqs: 1, ctx_q: 2048, ctx_kv: 2048, gen_reqs: 31, gen_kv: 2500 },
    ] {
        let ops = aiconfigurator::ops::decompose(&model, &silicon.cluster, &eng, &shape, 1.3);
        let truth = LatencyOracle::step_latency_us(&silicon, &ops);
        let est = db.step_latency_us(&ops);
        let err = (est - truth).abs() / truth;
        assert!(err < 0.25, "shape {shape:?}: est {est:.0} vs truth {truth:.0} ({err:.2})");
    }
}

#[test]
fn analytical_tpot_tracks_simulator_dense() {
    let (silicon, model, db) = fixture("qwen3-32b", Framework::TrtLlm);
    let eng = aiconfigurator::config::EngineConfig {
        framework: Framework::TrtLlm,
        parallel: aiconfigurator::config::ParallelSpec::tp(2),
        batch: 16,
        weight_dtype: Dtype::Fp8,
        kv_dtype: Dtype::Fp8,
        flags: aiconfigurator::config::RuntimeFlags::defaults_for(Framework::TrtLlm),
        placement: aiconfigurator::topology::Placement::packed(),
    };
    let wl = WorkloadSpec::new("qwen3-32b", 2048, 256, f64::INFINITY, 0.0);
    let cand = Candidate::Aggregated { engine: eng, replicas: 1 };
    let est = perfmodel::estimate(&db, &model, &silicon.cluster, &cand, &wl);
    let sim = AggregatedSim::new(&silicon, &model, &silicon.cluster, eng, SimConfig::default())
        .run(&closed_loop(32, 2048, 256));
    let err = (est.tpot_ms - sim.mean_tpot_ms()).abs() / sim.mean_tpot_ms();
    assert!(
        err < 0.30,
        "TPOT model {:.2} vs sim {:.2} ({err:.2})",
        est.tpot_ms,
        sim.mean_tpot_ms()
    );
}

#[test]
fn vllm_slower_than_trtllm_same_config() {
    // Framework heterogeneity must propagate end-to-end.
    let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, f64::INFINITY, 0.0);
    let mut results = Vec::new();
    for fw in [Framework::TrtLlm, Framework::Vllm] {
        let (silicon, model, db) = fixture("llama3.1-8b", fw);
        let eng = aiconfigurator::config::EngineConfig {
            framework: fw,
            parallel: aiconfigurator::config::ParallelSpec::tp(1),
            batch: 8,
            weight_dtype: Dtype::Fp8,
            kv_dtype: Dtype::Fp8,
            flags: aiconfigurator::config::RuntimeFlags::defaults_for(fw),
            placement: aiconfigurator::topology::Placement::packed(),
        };
        let cand = Candidate::Aggregated { engine: eng, replicas: 1 };
        results.push(perfmodel::estimate(&db, &model, &silicon.cluster, &cand, &wl));
    }
    assert!(
        results[1].tpot_ms > results[0].tpot_ms,
        "vLLM TPOT {} should exceed TRT-LLM {}",
        results[1].tpot_ms,
        results[0].tpot_ms
    );
}

#[test]
fn h200_beats_h100_on_decode_heavy_workload() {
    let model = by_name("qwen3-32b").unwrap();
    let wl = WorkloadSpec::new("qwen3-32b", 512, 1024, f64::INFINITY, 0.0);
    let mut thru = Vec::new();
    for gpu in [h100_sxm(), h200_sxm()] {
        let cluster = ClusterSpec::new(gpu, 8, 1);
        let silicon = Silicon::new(cluster, Framework::TrtLlm.profile());
        let db = PerfDatabase::build(&silicon, &model, Dtype::Fp8, 3);
        let eng = aiconfigurator::config::EngineConfig {
            framework: Framework::TrtLlm,
            parallel: aiconfigurator::config::ParallelSpec::tp(2),
            batch: 64,
            weight_dtype: Dtype::Fp8,
            kv_dtype: Dtype::Fp8,
            flags: aiconfigurator::config::RuntimeFlags::defaults_for(Framework::TrtLlm),
            placement: aiconfigurator::topology::Placement::packed(),
        };
        let cand = Candidate::Aggregated { engine: eng, replicas: 1 };
        thru.push(perfmodel::estimate(&db, &model, &cluster, &cand, &wl).thru_per_gpu);
    }
    assert!(thru[1] > thru[0] * 1.1, "H200 {} vs H100 {}", thru[1], thru[0]);
}

#[test]
fn modes_restriction_respected() {
    let (silicon, model, db) = fixture("llama3.1-8b", Framework::Sglang);
    let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
    let mut space = SearchSpace::default_for(&model, Framework::Sglang);
    space.modes = vec![ServingMode::Aggregated];
    let report = TaskRunner::new(&model, &silicon.cluster, space, wl).run(&db);
    assert!(report
        .evaluated
        .iter()
        .all(|e| matches!(e.cand, Candidate::Aggregated { .. })));
}

#[test]
fn db_persistence_roundtrip_via_files() {
    let (silicon, model, db) = fixture("mixtral-8x7b", Framework::TrtLlm);
    let dir = std::env::temp_dir().join(format!("aiconf_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("db.json");
    db.save(&path).unwrap();
    let loaded = PerfDatabase::load(&path, silicon.cluster).unwrap();
    assert_eq!(loaded.ctx, db.ctx);
    let op = aiconfigurator::ops::Op::Gemm { m: 333, n: 4096, k: 4096, dtype: Dtype::Fp8, count: 1 };
    assert!((loaded.op_latency_us(&op) - db.op_latency_us(&op)).abs() < 1e-3);
    let _ = std::fs::remove_dir_all(&dir);
    let _ = model;
}

#[test]
fn gpt_oss_and_mixtral_search_works() {
    // Non-headline models exercise the same pipeline.
    for name in ["gpt-oss-120b", "mixtral-8x7b"] {
        let (silicon, model, db) = fixture(name, Framework::TrtLlm);
        let wl = WorkloadSpec::new(name, 1024, 256, f64::INFINITY, 0.0);
        let space = SearchSpace::default_for(&model, Framework::TrtLlm);
        let report = TaskRunner::new(&model, &silicon.cluster, space, wl).run(&db);
        assert!(!report.evaluated.is_empty(), "{name} produced no candidates");
    }
}

//! Concurrency contracts of the service pipeline: request coalescing,
//! load shedding, warm-cache LRU eviction, and v1/v2 equivalence —
//! driven in-process (no sockets) so the tests control worker counts
//! and queue limits precisely.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};

use aiconfigurator::config::WorkloadSpec;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::service::{handle_request, make_request, make_request_v2, Pipeline, State};
use aiconfigurator::util::json::{self, Json};

/// A fast search request: single mode, small model.
fn search_req(isl: u32, id: u64) -> Json {
    let wl = WorkloadSpec::new("llama3.1-8b", isl, 64, 2000.0, 5.0);
    let mut req = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, id);
    req.set("modes", Json::Arr(vec![json::s("agg")]));
    req
}

/// Drop the envelope/wall-clock fields that legitimately differ between
/// two answers to the same logical request.
fn strip_volatile(mut j: Json) -> Json {
    if let Json::Obj(m) = &mut j {
        m.remove("v");
        m.remove("id");
        m.remove("elapsed_ms");
    }
    j
}

#[test]
fn coalesced_requests_share_one_computation_and_payload() {
    let pipeline = Pipeline::new(Arc::new(State::new(5)), 2, 64);
    // Fire salvos of identical requests (distinct ids — the coalescing
    // key ignores them) until at least one follower latched onto a
    // leader's flight. The first salvo almost always coalesces (the
    // leader holds the flight for the whole search), but the contract
    // is probabilistic per salvo, so retry a few times.
    let threads = 8usize;
    let mut rounds = 0usize;
    let mut responses = Vec::new();
    while pipeline.state().stats.coalesce_followers.load(Ordering::Relaxed) == 0 {
        rounds += 1;
        assert!(rounds <= 5, "no coalescing after {threads}x{rounds} identical requests");
        let barrier = Barrier::new(threads);
        responses = std::thread::scope(|sc| {
            let handles: Vec<_> = (0..threads)
                .map(|i| {
                    let (pipeline, barrier) = (&pipeline, &barrier);
                    sc.spawn(move || {
                        barrier.wait();
                        pipeline.handle(&search_req(1024, i as u64))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
    }
    let stats = &pipeline.state().stats;
    let leaders = stats.coalesce_leaders.load(Ordering::Relaxed);
    let followers = stats.coalesce_followers.load(Ordering::Relaxed);
    let total = (rounds * threads) as u64;
    assert!(followers >= 1);
    assert!(
        leaders < total,
        "coalescing must run fewer computations ({leaders}) than requests ({total})"
    );
    // Every coalesced answer is bit-identical to an uncoalesced run of
    // the same request (modulo envelope + wall clock).
    let lone = strip_volatile(pipeline.handle(&search_req(1024, 999)));
    for r in responses {
        assert_eq!(r.req_str("status").unwrap(), "ok");
        assert_eq!(strip_volatile(r), lone, "coalesced payload must match uncoalesced");
    }
}

#[test]
fn overload_sheds_with_typed_errors_instead_of_hanging() {
    // One worker, backlog of one: concurrent distinct requests (unique
    // isl → unique coalescing keys) must overflow admission.
    let pipeline = Pipeline::new(Arc::new(State::new(6)), 1, 1);
    // Warm the context first so the salvo doesn't serialize on the
    // single-flight database build.
    assert_eq!(pipeline.handle(&search_req(4096, 0)).req_str("status").unwrap(), "ok");

    // Salvos of concurrent *distinct* v2 requests (unique isl per
    // request → unique coalescing keys). With one worker and a backlog
    // of one, a simultaneous salvo of 6 must overflow admission; retry
    // a few salvos in case the worker drains unusually fast.
    let threads = 6usize;
    let mut rounds = 0usize;
    let mut responses: Vec<Json> = Vec::new();
    while pipeline.state().stats.shed.load(Ordering::Relaxed) == 0 {
        rounds += 1;
        assert!(rounds <= 5, "no shedding after {rounds} salvos at queue_limit=1");
        let barrier = Barrier::new(threads);
        responses = std::thread::scope(|sc| {
            let handles: Vec<_> = (0..threads)
                .map(|i| {
                    let (pipeline, barrier) = (&pipeline, &barrier);
                    sc.spawn(move || {
                        barrier.wait();
                        // Distinct isl per thread and per round.
                        let isl = 256 + 64 * (rounds * threads + i) as u32;
                        let wl = WorkloadSpec::new("llama3.1-8b", isl, 64, 2000.0, 5.0);
                        let mut req =
                            make_request_v2(&wl, "h100", 8, 1, Framework::TrtLlm, i as u64);
                        req.set("modes", Json::Arr(vec![json::s("agg")]));
                        pipeline.handle(&req)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
    }
    let shed: Vec<&Json> =
        responses.iter().filter(|r| r.req_str("status").unwrap() == "error").collect();
    let ok = responses.iter().filter(|r| r.req_str("status").unwrap() == "ok").count();
    assert!(!shed.is_empty(), "at least one request of the salvo must be shed");
    assert!(ok >= 1, "admitted requests must still be answered");
    for r in &shed {
        // The v2 dialect carries the typed refusal, not a hang and not
        // a bare string.
        let err = r.req("error").unwrap();
        assert_eq!(err.req_str("code").unwrap(), "overloaded", "{r:?}");
        assert!(err.req_str("message").unwrap().contains("queue"), "{r:?}");
    }
    assert!(pipeline.state().stats.shed.load(Ordering::Relaxed) >= 1);
    assert!(pipeline.state().stats.errors.load(Ordering::Relaxed) >= 1);
}

#[test]
fn warm_cache_evicts_lru_context_but_keeps_hit_rate() {
    // Capacity 2, three contexts (distinct gpus_per_node): the access
    // pattern A B A C A keeps the hot context resident and evicts the
    // cold one.
    let st = State::with_caps(7, None, 2);
    let req_for = |gpn: u32, id: u64| {
        let wl = WorkloadSpec::new("llama3.1-8b", 512, 64, 2000.0, 5.0);
        let mut req = make_request(&wl, "h100", gpn, 1, Framework::TrtLlm, id);
        req.set("modes", Json::Arr(vec![json::s("agg")]));
        req
    };
    for (i, gpn) in [8u32, 4, 8, 2, 8].iter().enumerate() {
        let resp = handle_request(&req_for(*gpn, i as u64), &st).unwrap();
        assert_eq!(resp.req_str("status").unwrap(), "ok");
        assert!(st.cache().len() <= 2, "cache must stay within its capacity");
    }
    let (hits, misses, evictions) = st.cache().stats();
    assert_eq!(misses, 3, "three distinct contexts were built");
    assert_eq!(hits, 2, "the hot context must be answered warm");
    assert!(evictions >= 1, "capacity 2 with 3 contexts must evict");
    let key_of = |gpn: u32| {
        ("llama3.1-8b".to_string(), "h100".to_string(), gpn, 1, "trtllm".to_string(), "legacy".to_string())
    };
    assert!(st.cache().peek(&key_of(8)).is_some(), "the hot context stays resident");
    assert!(st.cache().peek(&key_of(4)).is_none(), "the LRU context is evicted");
}

#[test]
fn v1_and_v2_envelopes_answer_equivalently() {
    let pipeline = Pipeline::new(Arc::new(State::new(8)), 0, 0);
    let wl = WorkloadSpec::new("llama3.1-8b", 768, 96, 2000.0, 5.0);
    let mut v1 = make_request(&wl, "h100", 8, 1, Framework::TrtLlm, 1);
    v1.set("modes", Json::Arr(vec![json::s("agg")]));
    let mut v2 = make_request_v2(&wl, "h100", 8, 1, Framework::TrtLlm, 2);
    v2.set("modes", Json::Arr(vec![json::s("agg")]));

    let r1 = pipeline.handle(&v1);
    let r2 = pipeline.handle(&v2);
    assert_eq!(r1.req_f64("v").unwrap(), 1.0);
    assert_eq!(r2.req_f64("v").unwrap(), 2.0);
    assert_eq!(r1.req_f64("id").unwrap(), 1.0);
    assert_eq!(r2.req_f64("id").unwrap(), 2.0);
    assert_eq!(
        strip_volatile(r1),
        strip_volatile(r2),
        "the two dialects must answer byte-identically modulo the envelope"
    );

    // The stats op reports the traffic above with queue gauges and
    // latency quantiles.
    let stats = pipeline.handle(&json::parse(r#"{"v": 2, "op": "stats"}"#).unwrap());
    assert_eq!(stats.req_str("status").unwrap(), "ok");
    let s = stats.req("stats").unwrap();
    assert_eq!(s.req("requests").unwrap().req("search").unwrap().req_f64("count").unwrap(), 2.0);
    assert!(s.req("requests").unwrap().req("search").unwrap().req_f64("p50_ms").unwrap() > 0.0);
    assert!(s.req("pool").unwrap().req_f64("queue_depth").unwrap() >= 0.0);
    assert!(s.req("pool").unwrap().req_f64("queue_limit").unwrap() >= 1.0);
    assert_eq!(s.req("cache").unwrap().req_f64("entries").unwrap(), 1.0);
    assert!(stats.req_str("metrics_text").unwrap().contains("aiconf_queue_depth"));
}

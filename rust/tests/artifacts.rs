//! Schema checks for every committed JSON artifact (the CI
//! `artifacts-validate` job): `BENCH_*.json` at the repo root, the
//! kernel-measurement sets under `artifacts/measurements/`, the trace
//! specs under `artifacts/traces/`, any committed calibration
//! artifacts under `artifacts/calibration/`, and
//! the AOT manifest if present — so a hand-edited file fails CI with a
//! named path instead of silently rotting until a downstream consumer
//! trips over it.

use std::path::{Path, PathBuf};

use aiconfigurator::hardware::gpu_by_name;
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::frameworks::Framework;
use aiconfigurator::perfdb::measure;
use aiconfigurator::perfdb::CalibrationArtifact;
use aiconfigurator::planner::TrafficModel;
use aiconfigurator::runtime::Manifest;
use aiconfigurator::search::SearchDelta;
use aiconfigurator::util::json::{self, Json};

fn repo_root() -> PathBuf {
    // CARGO_MANIFEST_DIR is <repo>/rust.
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}

/// Every `BENCH_*.json` at the repo root must be a flat object with a
/// `bench` name string; metric values are numbers, strings, bools,
/// nulls or arrays of those (pending benches commit nulls until a
/// toolchain-equipped machine overwrites them with measured medians).
#[test]
fn bench_artifacts_are_wellformed() {
    let root = repo_root();
    let mut found = 0;
    for entry in std::fs::read_dir(&root).unwrap() {
        let path = entry.unwrap().path();
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        if !(name.starts_with("BENCH_") && name.ends_with(".json")) {
            continue;
        }
        found += 1;
        let txt = std::fs::read_to_string(&path).unwrap();
        let j = json::parse(&txt).unwrap_or_else(|e| panic!("{name}: invalid JSON: {e}"));
        assert!(
            j.req_str("bench").is_ok(),
            "{name}: missing required string field 'bench'"
        );
        let Json::Obj(map) = &j else {
            panic!("{name}: top level must be an object");
        };
        for (k, v) in map {
            let flat_ok = |x: &Json| {
                matches!(x, Json::Null | Json::Bool(_) | Json::Num(_) | Json::Str(_))
            };
            let ok = match v {
                Json::Arr(items) => items.iter().all(flat_ok),
                other => flat_ok(other),
            };
            assert!(ok, "{name}: field '{k}' must be a flat value or array of flat values");
        }
    }
    assert!(found >= 1, "no BENCH_*.json found at {}", root.display());
}

/// The committed BENCH_plan.json placeholder (or its measured
/// overwrite) must keep the keys benches/planner.rs writes.
#[test]
fn bench_plan_keeps_its_contract() {
    let txt = std::fs::read_to_string(repo_root().join("BENCH_plan.json")).unwrap();
    let j = json::parse(&txt).unwrap();
    assert_eq!(j.req_str("bench").unwrap(), "planner");
    for key in [
        "cold_plan_ms_median",
        "warm_plan_ms_median",
        "warm_speedup",
        "total_cost_usd",
        "static_peak_cost_usd",
        "options_considered",
        "options_pruned",
        "cold_plan_options_per_s",
    ] {
        let v = j.req(key).unwrap_or_else(|e| panic!("BENCH_plan.json: {e}"));
        assert!(
            matches!(v, Json::Null | Json::Num(_)),
            "BENCH_plan.json: '{key}' must be a number or null (pending)"
        );
    }
}

/// The committed BENCH_service.json placeholder (or its measured
/// overwrite) must keep the keys benches/service.rs writes; a measured
/// run must additionally prove the closed loop coalesced and shed
/// nothing under its oversized admission queue.
#[test]
fn bench_service_keeps_its_contract() {
    let txt = std::fs::read_to_string(repo_root().join("BENCH_service.json")).unwrap();
    let j = json::parse(&txt).unwrap();
    assert_eq!(j.req_str("bench").unwrap(), "service");
    for key in [
        "clients",
        "requests_total",
        "elapsed_s",
        "throughput_rps",
        "p50_ms",
        "p99_ms",
        "coalesce_rate",
        "cache_hit_rate",
        "shed_total",
        "errors",
    ] {
        let v = j.req(key).unwrap_or_else(|e| panic!("BENCH_service.json: {e}"));
        assert!(
            matches!(v, Json::Null | Json::Num(_)),
            "BENCH_service.json: '{key}' must be a number or null (pending)"
        );
    }
    // A measured run (non-null requests_total) must show coalescing and
    // a clean, unshed mix — the bench's own acceptance bar.
    if let Some(total) = j.req("requests_total").unwrap().as_f64() {
        assert!(total >= 100.0, "closed loop must drive hundreds of requests");
        assert!(j.req_f64("coalesce_rate").unwrap() > 0.0);
        assert!(j.req_f64("cache_hit_rate").unwrap() > 0.5);
        assert_eq!(j.req_f64("shed_total").unwrap(), 0.0);
        assert_eq!(j.req_f64("errors").unwrap(), 0.0);
    }
}

/// The committed BENCH_topology.json placeholder (or its measured
/// overwrite) must keep the keys benches/topology.rs writes, and its
/// fabric list must name real presets.
#[test]
fn bench_topology_keeps_its_contract() {
    let txt = std::fs::read_to_string(repo_root().join("BENCH_topology.json")).unwrap();
    let j = json::parse(&txt).unwrap();
    assert_eq!(j.req_str("bench").unwrap(), "topology");
    for key in [
        "shapes",
        "placements_total",
        "enumerate_ms_median",
        "collective_price_ms_median",
        "grid_legacy_ms_median",
        "grid_tiered_ms_median",
        "grid_legacy_engines",
        "grid_tiered_engines",
        "grid_legacy_candidates_per_s",
        "grid_tiered_candidates_per_s",
    ] {
        let v = j.req(key).unwrap_or_else(|e| panic!("BENCH_topology.json: {e}"));
        assert!(
            matches!(v, Json::Null | Json::Num(_)),
            "BENCH_topology.json: '{key}' must be a number or null (pending)"
        );
    }
    let fabrics = j
        .req("fabrics")
        .unwrap()
        .as_arr()
        .expect("BENCH_topology.json: 'fabrics' must be an array");
    assert!(!fabrics.is_empty());
    for f in fabrics {
        let name = f.as_str().expect("fabric entries must be strings");
        assert!(
            aiconfigurator::topology::fabric::by_name(name, 8).is_some(),
            "BENCH_topology.json names unknown fabric '{name}'"
        );
    }
    // A measured run must report at least two placements per shape on
    // average across the tiered presets (the axis exists); the pending
    // placeholder carries nulls and is exempt.
    if let (Some(shapes), Some(total)) = (
        j.req("shapes").unwrap().as_f64(),
        j.req("placements_total").unwrap().as_f64(),
    ) {
        assert!(total >= shapes, "fewer placements than shapes: {total} < {shapes}");
    }
}

/// The committed BENCH_validate.json placeholder (or its measured
/// overwrite) must keep the keys benches/validate.rs writes; a measured
/// benign replay must stay inside the CI optimism-gap gate.
#[test]
fn bench_validate_keeps_its_contract() {
    let txt = std::fs::read_to_string(repo_root().join("BENCH_validate.json")).unwrap();
    let j = json::parse(&txt).unwrap();
    assert_eq!(j.req_str("bench").unwrap(), "validate");
    for key in [
        "windows",
        "trace_requests",
        "replay_benign_ms_median",
        "replay_injected_ms_median",
        "benign_optimism_gap",
        "injected_achieved_attainment",
        "injected_failures",
    ] {
        let v = j.req(key).unwrap_or_else(|e| panic!("BENCH_validate.json: {e}"));
        assert!(
            matches!(v, Json::Null | Json::Num(_)),
            "BENCH_validate.json: '{key}' must be a number or null (pending)"
        );
    }
    // A measured run (non-null trace_requests) replayed a real trace,
    // and its faithful-execution gap honors the validate-smoke bar.
    if let Some(reqs) = j.req("trace_requests").unwrap().as_f64() {
        assert!(reqs >= 100.0, "bench trace must carry hundreds of requests");
        assert!(
            j.req_f64("benign_optimism_gap").unwrap() <= 0.10,
            "benign replay gap exceeds the 10% CI gate"
        );
    }
}

/// Validate one Chrome trace-event export (what `--trace-out` writes):
/// `displayTimeUnit` "ms", a `traceEvents` array of complete "X"
/// events, each carrying finite `pid`/`tid`/`ts` and a non-negative
/// `dur` — the shape chrome://tracing and Perfetto both load.
fn assert_chrome_trace_schema(name: &str, j: &Json) {
    assert_eq!(j.str_or("displayTimeUnit", ""), "ms", "{name}: displayTimeUnit must be 'ms'");
    let events = j
        .req("traceEvents")
        .unwrap_or_else(|e| panic!("{name}: {e}"))
        .as_arr()
        .unwrap_or_else(|| panic!("{name}: traceEvents must be an array"));
    assert!(!events.is_empty(), "{name}: trace holds no events");
    for (i, e) in events.iter().enumerate() {
        assert!(!e.str_or("name", "").is_empty(), "{name}: event {i} has no name");
        assert_eq!(e.str_or("ph", ""), "X", "{name}: event {i} must be a complete 'X' event");
        for key in ["pid", "tid", "ts"] {
            let v = e.req_f64(key).unwrap_or_else(|err| panic!("{name}: event {i}: {err}"));
            assert!(v.is_finite(), "{name}: event {i}: '{key}' must be finite");
        }
        let dur = e.req_f64("dur").unwrap_or_else(|err| panic!("{name}: event {i}: {err}"));
        assert!(dur.is_finite() && dur >= 0.0, "{name}: event {i}: bad dur {dur}");
    }
}

/// The committed BENCH_trace.json placeholder (or its measured
/// overwrite) must keep the keys benches/trace.rs writes; a measured
/// run must hold tracing overhead under the 5% acceptance bar.
#[test]
fn bench_trace_keeps_its_contract() {
    let txt = std::fs::read_to_string(repo_root().join("BENCH_trace.json")).unwrap();
    let j = json::parse(&txt).unwrap();
    assert_eq!(j.req_str("bench").unwrap(), "trace");
    for key in [
        "search_off_ms_median",
        "search_on_ms_median",
        "overhead_frac",
        "spans_recorded",
    ] {
        let v = j.req(key).unwrap_or_else(|e| panic!("BENCH_trace.json: {e}"));
        assert!(
            matches!(v, Json::Null | Json::Num(_)),
            "BENCH_trace.json: '{key}' must be a number or null (pending)"
        );
    }
    // A measured run (non-null medians) must keep recording cheap: the
    // traced search may regress the untraced median by at most 5%.
    if let Some(on) = j.req("search_on_ms_median").unwrap().as_f64() {
        let off = j.req_f64("search_off_ms_median").unwrap();
        assert!(off > 0.0, "BENCH_trace.json: off-median must be positive");
        let frac = j.req_f64("overhead_frac").unwrap();
        assert!(
            frac <= 0.05,
            "BENCH_trace.json: tracing overhead {frac:.4} exceeds the 5% budget \
             (off {off:.2} ms, on {on:.2} ms)"
        );
        assert!(j.req_f64("spans_recorded").unwrap() > 0.0);
    }
}

/// Every Chrome trace the trace-smoke job wrote under
/// rust/target/trace-smoke/ must satisfy the trace-event schema (the
/// job runs `search --trace-out` / `plan --trace-out` first, then this
/// test validates what landed on disk).
#[test]
fn trace_smoke_outputs_are_valid_chrome_traces() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("target").join("trace-smoke");
    if !dir.is_dir() {
        return; // smoke job not run locally
    }
    let mut found = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        if !name.ends_with("-trace.json") {
            continue;
        }
        found += 1;
        let txt = std::fs::read_to_string(&path).unwrap();
        let j = json::parse(&txt).unwrap_or_else(|e| panic!("{name}: invalid JSON: {e}"));
        assert_chrome_trace_schema(&name, &j);
    }
    assert!(found >= 1, "trace-smoke dir exists but holds no *-trace.json");
}

/// Every committed trace spec under artifacts/traces/ must satisfy the
/// `validate --trace-spec` contract: `"kind": "trace-spec"`, a traffic
/// model that parses and validates, a positive horizon, sane jitter,
/// and an exactly-representable seed (main.rs enforces the same at the
/// CLI; this pins the committed files themselves).
#[test]
fn trace_specs_validate() {
    let dir = repo_root().join("artifacts").join("traces");
    assert!(dir.is_dir(), "artifacts/traces is committed by this repo and must exist");
    let mut found = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        if !path.extension().is_some_and(|x| x == "json") {
            continue;
        }
        found += 1;
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        let txt = std::fs::read_to_string(&path).unwrap();
        let j = json::parse(&txt).unwrap_or_else(|e| panic!("{name}: invalid JSON: {e}"));
        assert_eq!(j.str_or("kind", ""), "trace-spec", "{name}: wrong kind");
        let traffic = TrafficModel::from_json(j.req("traffic").unwrap())
            .unwrap_or_else(|e| panic!("{name}: bad traffic model: {e}"));
        traffic.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        let windows = j.req_f64("windows").unwrap_or_else(|e| panic!("{name}: {e}"));
        let window_h = j.req_f64("window_hours").unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(windows >= 1.0 && windows.fract() == 0.0, "{name}: windows must be a count");
        assert!(window_h > 0.0, "{name}: window_hours must be positive");
        let jitter = j.f64_or("len_jitter", 0.0);
        assert!((0.0..1.0).contains(&jitter), "{name}: len_jitter must be in [0, 1)");
        let seed = j.f64_or("seed", 0.0);
        assert!(
            seed >= 0.0 && seed.fract() == 0.0 && seed < 9.0e15,
            "{name}: seed must be a non-negative integer the f64 wire format preserves"
        );
    }
    assert!(found >= 1, "artifacts/traces holds no trace specs");
}

/// The committed BENCH_replan.json placeholder (or its measured
/// overwrite) must keep the keys benches/replan.rs writes; a measured
/// run must show the incremental replan beating the full re-search —
/// the differential layer's entire reason to exist.
#[test]
fn bench_replan_keeps_its_contract() {
    let txt = std::fs::read_to_string(repo_root().join("BENCH_replan.json")).unwrap();
    let j = json::parse(&txt).unwrap();
    assert_eq!(j.req_str("bench").unwrap(), "replan");
    for key in [
        "baseline_priced_configs",
        "full_resweep_ms_median",
        "replan_window_ms_median",
        "replan_reprice_ms_median",
        "replan_addleg_ms_median",
        "addleg_repriced_configs",
        "window_speedup",
        "addleg_speedup",
    ] {
        let v = j.req(key).unwrap_or_else(|e| panic!("BENCH_replan.json: {e}"));
        assert!(
            matches!(v, Json::Null | Json::Num(_)),
            "BENCH_replan.json: '{key}' must be a number or null (pending)"
        );
    }
    // A measured run (non-null full_resweep_ms_median) must show the
    // demand-side replan at least matching the full re-search and the
    // structural replan re-pricing a strict subset.
    if let Some(full) = j.req("full_resweep_ms_median").unwrap().as_f64() {
        assert!(
            j.req_f64("replan_window_ms_median").unwrap() <= full,
            "window-edit replan slower than a full re-search"
        );
        let baseline = j.req_f64("baseline_priced_configs").unwrap();
        let repriced = j.req_f64("addleg_repriced_configs").unwrap();
        assert!(
            repriced < baseline,
            "add-leg replan re-priced {repriced} of {baseline} configs — nothing saved"
        );
    }
}

/// Every committed delta scenario under artifacts/deltas/ must satisfy
/// the `replan --delta` contract: `"kind": "search-delta"`, fields that
/// parse and validate through [`SearchDelta::from_json`], and leg/GPU
/// tokens that resolve against the hardware presets — so the CI
/// replan-smoke job can never be fed a scenario the CLI would reject.
#[test]
fn delta_specs_validate() {
    let dir = repo_root().join("artifacts").join("deltas");
    assert!(dir.is_dir(), "artifacts/deltas is committed by this repo and must exist");
    let mut found = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        if !path.extension().is_some_and(|x| x == "json") {
            continue;
        }
        found += 1;
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        let txt = std::fs::read_to_string(&path).unwrap();
        let j = json::parse(&txt).unwrap_or_else(|e| panic!("{name}: invalid JSON: {e}"));
        let d = SearchDelta::from_json(&j).unwrap_or_else(|e| panic!("{name}: {e}"));
        for (gpu, _) in &d.reprice {
            assert!(gpu_by_name(gpu).is_some(), "{name}: reprices unknown gpu '{gpu}'");
        }
        for leg in d.recalibrate.iter().chain(&d.add_legs).chain(&d.remove_legs) {
            aiconfigurator::hardware::parse_fleet_leg(leg, 8)
                .unwrap_or_else(|e| panic!("{name}: bad leg token '{leg}': {e}"));
        }
        // Round-trip: the wire format regenerates an equal delta.
        let back = SearchDelta::from_json(&d.to_json())
            .unwrap_or_else(|e| panic!("{name}: to_json round-trip: {e}"));
        assert_eq!(back, d, "{name}: to_json/from_json round-trip drifted");
    }
    assert!(found >= 1, "artifacts/deltas holds no delta scenarios");
}

/// Every measurement set under artifacts/measurements/<gpu>/ parses,
/// validates, names a known context, and matches its directory/file
/// placement (measure::load_dir enforces gpu + table-name agreement).
#[test]
fn measurement_sets_validate() {
    let dir = repo_root().join("artifacts").join("measurements");
    assert!(
        dir.is_dir(),
        "artifacts/measurements is committed by this repo and must exist"
    );
    let mut gpus = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let gpu_dir = entry.unwrap().path();
        if !gpu_dir.is_dir() {
            continue;
        }
        gpus += 1;
        let gpu = gpu_dir.file_name().unwrap().to_string_lossy().to_string();
        assert!(
            gpu_by_name(&gpu).is_some(),
            "measurement dir '{gpu}' does not name a known GPU"
        );
        let sets = measure::load_dir(&dir, &gpu)
            .unwrap_or_else(|e| panic!("loading measurements for {gpu}: {e}"));
        assert!(!sets.is_empty());
        for set in &sets {
            assert!(
                by_name(&set.model).is_some(),
                "{gpu}/{}: unknown model '{}'",
                set.table.name(),
                set.model
            );
            assert!(
                Framework::parse(&set.framework).is_some(),
                "{gpu}/{}: unknown framework '{}'",
                set.table.name(),
                set.framework
            );
            assert!(
                Dtype::parse(&set.kv_dtype).is_some(),
                "{gpu}/{}: unknown kv dtype '{}'",
                set.table.name(),
                set.kv_dtype
            );
            assert!(
                !set.entries.is_empty(),
                "{gpu}/{}: empty measurement set",
                set.table.name()
            );
        }
    }
    assert!(gpus >= 1, "artifacts/measurements has no <gpu> directories");
}

/// Committed calibration artifacts (if any) must load — version, grid
/// shape, fit tables and measured cells are all validated by
/// CalibrationArtifact::load.
#[test]
fn calibration_artifacts_validate() {
    let dir = repo_root().join("artifacts").join("calibration");
    if !dir.is_dir() {
        return; // none committed (CI writes its own under rust/target)
    }
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().is_some_and(|x| x == "json") {
            CalibrationArtifact::load(&path)
                .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        }
    }
}

/// The AOT manifest (if `make artifacts` has been run) must agree with
/// the compiled-in grid geometry.
#[test]
fn aot_manifest_matches_contract_when_present() {
    let path = repo_root().join("artifacts").join("manifest.json");
    if !path.exists() {
        return;
    }
    let m = Manifest::load(&path).unwrap();
    m.check_contract().unwrap();
}

/// Catch-all: every .json anywhere under artifacts/ at least parses.
#[test]
fn all_artifact_json_parses() {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
        for entry in std::fs::read_dir(dir).unwrap() {
            let p = entry.unwrap().path();
            if p.is_dir() {
                walk(&p, out);
            } else if p.extension().is_some_and(|x| x == "json") {
                out.push(p);
            }
        }
    }
    let dir = repo_root().join("artifacts");
    if !dir.is_dir() {
        return;
    }
    let mut files = Vec::new();
    walk(&dir, &mut files);
    assert!(!files.is_empty(), "artifacts/ exists but holds no JSON");
    for p in files {
        let txt = std::fs::read_to_string(&p).unwrap();
        json::parse(&txt).unwrap_or_else(|e| panic!("{}: invalid JSON: {e}", p.display()));
    }
}

//! Tracing must be free when off and inert when on: a search run with
//! no recorder installed and one recorded end-to-end must produce
//! bit-identical reports — the trace artifact is the only difference.
//! Also pins the span structure the pipeline emits (search → grid
//! build → pricing → frontier merge; plan → per-leg sweep → schedule)
//! and the Chrome export of a real run.

use aiconfigurator::config::WorkloadSpec;
use aiconfigurator::frameworks::Framework;
use aiconfigurator::hardware::{h100_sxm, ClusterSpec};
use aiconfigurator::models::{by_name, Dtype};
use aiconfigurator::perfdb::{LatencyOracle, PerfDatabase};
use aiconfigurator::planner::{self, PlanSpec, TrafficModel};
use aiconfigurator::search::{RunOptions, SearchReport, SearchSpace, TaskRunner};
use aiconfigurator::silicon::Silicon;
use aiconfigurator::trace;
use aiconfigurator::util::json;

fn fixture(model: &str) -> (ClusterSpec, aiconfigurator::models::ModelArch, PerfDatabase) {
    let cluster = ClusterSpec::new(h100_sxm(), 8, 1);
    let silicon = Silicon::new(cluster, Framework::TrtLlm.profile());
    let m = by_name(model).unwrap();
    let db = PerfDatabase::build(&silicon, &m, Dtype::Fp8, 0x5EED);
    (cluster, m, db)
}

/// Everything in a report except wall-clock timings must match.
fn assert_same_results(a: &SearchReport, b: &SearchReport) {
    assert_eq!(a.configs_priced, b.configs_priced);
    assert_eq!(a.pruned, b.pruned);
    assert_eq!(a.pruned_sla, b.pruned_sla);
    assert_eq!(a.pruned_dominated, b.pruned_dominated);
    assert_eq!(a.infeasible, b.infeasible);
    assert_eq!(a.evaluated.len(), b.evaluated.len());
    for (x, y) in a.evaluated.iter().zip(&b.evaluated) {
        assert_eq!(x.cand, y.cand, "candidate order must not depend on tracing");
        assert_eq!(x.est, y.est, "estimates must be bit-identical with tracing on");
    }
    assert_eq!(a.flag_summaries.len(), b.flag_summaries.len());
    assert_eq!(a.tier_counts.is_some(), b.tier_counts.is_some());
}

#[test]
fn tracing_on_is_bit_identical_to_tracing_off() {
    let (cluster, model, db) = fixture("qwen3-32b");
    let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
    space.batch = vec![8, 32, 128];
    space.max_x = 8;
    space.max_y = 8;
    let wl = WorkloadSpec::new("qwen3-32b", 2048, 256, 1500.0, 20.0);
    let runner = TaskRunner::new(&model, &cluster, space, wl);
    let opts = RunOptions { prune: true };

    assert!(!trace::enabled(), "test thread must start untraced");
    let off = runner.run_with(&db as &dyn LatencyOracle, &opts);

    let rec = trace::Recorder::new();
    rec.install();
    let on = runner.run_with(&db as &dyn LatencyOracle, &opts);
    let tr = rec.finish();
    assert!(!trace::enabled(), "finish must uninstall the recorder");

    assert_same_results(&off, &on);
    assert!(!tr.is_empty(), "the traced run must have recorded spans");
}

#[test]
fn search_emits_the_pipeline_spans() {
    let (cluster, model, db) = fixture("llama3.1-8b");
    let mut space = SearchSpace::default_for(&model, Framework::TrtLlm);
    space.batch = vec![8, 32];
    let wl = WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0);
    let runner = TaskRunner::new(&model, &cluster, space, wl);

    let rec = trace::Recorder::new();
    rec.install();
    let _report = runner.run(&db as &dyn LatencyOracle);
    let tr = rec.finish();

    let names: Vec<&str> = tr.spans.iter().map(|s| s.name.as_str()).collect();
    for want in ["grid_build", "price", "frontier_merge"] {
        assert!(names.contains(&want), "missing span '{want}' in {names:?}");
    }
    // The pricing span carries its batch size as a counter.
    let price = tr.spans.iter().find(|s| s.name == "price").unwrap();
    assert!(
        price.counters.iter().any(|(k, v)| *k == "jobs" && *v > 0.0),
        "price span should count jobs: {:?}",
        price.counters
    );
    // The export of a real run is valid Chrome trace-event JSON.
    let j = tr.to_chrome_json();
    assert_eq!(j.str_or("displayTimeUnit", ""), "ms");
    let events = j.req("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), tr.len());
    for e in events {
        assert_eq!(e.str_or("ph", ""), "X");
        assert!(e.req_f64("ts").unwrap().is_finite());
        assert!(e.req_f64("dur").unwrap() >= 0.0);
    }
    assert!(json::parse(&j.to_string()).is_ok(), "export must round-trip");
    // The tree render names every thread once and starts with the header.
    let txt = tr.render_tree();
    assert!(txt.starts_with("trace: "), "{txt}");
}

#[test]
fn plan_emits_leg_and_schedule_spans_and_stays_bit_identical() {
    let (cluster, model, db) = fixture("llama3.1-8b");
    let spec = PlanSpec {
        workload: WorkloadSpec::new("llama3.1-8b", 1024, 128, 2000.0, 10.0),
        traffic: TrafficModel::Ramp { start_qps: 2.0, end_qps: 20.0 },
        windows: 4,
        window_h: 1.0,
        max_gpus: None,
        prune: true,
        demand_override: Vec::new(),
    };
    let fleet: Vec<(ClusterSpec, &dyn LatencyOracle)> = vec![(cluster, &db)];

    let off = planner::plan(&model, Framework::TrtLlm, &spec, &fleet).unwrap();

    let rec = trace::Recorder::new();
    rec.install();
    let on = planner::plan(&model, Framework::TrtLlm, &spec, &fleet).unwrap();
    let tr = rec.finish();

    assert_eq!(
        off.to_json(&spec.workload).to_string(),
        on.to_json(&spec.workload).to_string(),
        "the plan must be bit-identical with tracing on"
    );
    let names: Vec<&str> = tr.spans.iter().map(|s| s.name.as_str()).collect();
    assert!(names.contains(&"plan"), "{names:?}");
    assert!(names.iter().any(|n| n.starts_with("leg_sweep")), "{names:?}");
    assert!(names.contains(&"schedule"), "{names:?}");
    // Category totals roll up for the service's aiconf_span_* series.
    let totals = tr.cat_totals();
    let plan_count = totals.iter().find(|(c, _, _)| *c == "plan").unwrap().2;
    assert!(plan_count >= 3, "plan spans under the 'plan' category: {totals:?}");
}

#!/usr/bin/env python3
"""Perf-budget gate: compare freshly measured bench medians against the
committed BENCH_*.json baselines.

Usage:
    python3 python/bench_budget.py --baseline <dir> --current <dir> \
        [--tolerance 0.15] [--files BENCH_plan.json BENCH_topology.json]

Only keys ending in ``_ms_median`` are budgeted (throughput and count
fields are informational; they track the same runs and would double-
count a regression). A run is a **regression** when
``current > baseline * (1 + tolerance)``.

Committed baselines start life as ``null`` (the repo's benches have
never run on a toolchain-equipped reference machine). A null baseline —
or a null/missing current value — is a visible SKIP, not a failure:
the gate degrades to a no-op until someone runs ``make bench-plan``
/ ``make bench-topo`` on reference hardware and commits the numbers.

Exit status: 1 if any budgeted key regressed, 0 otherwise (including
the all-skipped case).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_FILES = [
    "BENCH_plan.json",
    "BENCH_topology.json",
    "BENCH_replan.json",
    "BENCH_trace.json",
]
BUDGET_SUFFIX = "_ms_median"


def load(path: str):
    try:
        with open(path, "r", encoding="utf-8") as fh:
            return json.load(fh)
    except FileNotFoundError:
        return None
    except json.JSONDecodeError as e:
        print(f"bench-budget: {path}: malformed JSON ({e})", file=sys.stderr)
        return None


def compare_file(name: str, baseline_dir: str, current_dir: str, tol: float):
    """Returns (regressions, checked, skipped) for one BENCH file."""
    base = load(os.path.join(baseline_dir, name))
    cur = load(os.path.join(current_dir, name))
    if base is None:
        print(f"  {name}: SKIP — no baseline file in {baseline_dir}")
        return ([], 0, 1)
    if cur is None:
        print(f"  {name}: SKIP — no current file in {current_dir}")
        return ([], 0, 1)

    regressions = []
    checked = 0
    skipped = 0
    for key in sorted(k for k in base if k.endswith(BUDGET_SUFFIX)):
        b = base.get(key)
        c = cur.get(key)
        if not isinstance(b, (int, float)):
            print(f"  {name}:{key}: SKIP — baseline is null (bench never "
                  f"committed a reference run; gate is a no-op for this key)")
            skipped += 1
            continue
        if not isinstance(c, (int, float)):
            print(f"  {name}:{key}: SKIP — current value is null/missing")
            skipped += 1
            continue
        checked += 1
        if b <= 0:
            print(f"  {name}:{key}: SKIP — non-positive baseline {b}")
            skipped += 1
            continue
        ratio = c / b
        if ratio > 1.0 + tol:
            regressions.append((name, key, b, c, ratio))
            print(f"  {name}:{key}: REGRESSION {b:.3f} -> {c:.3f} ms "
                  f"({ratio:.2f}x, budget {1.0 + tol:.2f}x)")
        elif ratio < 1.0 - tol:
            print(f"  {name}:{key}: improved {b:.3f} -> {c:.3f} ms "
                  f"({ratio:.2f}x) — consider refreshing the committed baseline")
        else:
            print(f"  {name}:{key}: ok {b:.3f} -> {c:.3f} ms ({ratio:.2f}x)")
    return (regressions, checked, skipped)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="dir holding the committed BENCH_*.json snapshots")
    ap.add_argument("--current", required=True, help="dir holding the freshly measured BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.15, help="allowed fractional slowdown (default 0.15)")
    ap.add_argument("--files", nargs="*", default=DEFAULT_FILES, help="BENCH files to budget")
    args = ap.parse_args()

    print(f"bench-budget: medians vs baselines, tolerance +{args.tolerance:.0%}")
    all_regressions = []
    total_checked = 0
    total_skipped = 0
    for name in args.files:
        regs, checked, skipped = compare_file(name, args.baseline, args.current, args.tolerance)
        all_regressions.extend(regs)
        total_checked += checked
        total_skipped += skipped

    if total_checked == 0:
        print("bench-budget: NOTICE — every budgeted key was skipped "
              "(null baselines). The gate enforced nothing this run; commit "
              "reference medians to arm it.")
        return 0
    if all_regressions:
        print(f"bench-budget: FAIL — {len(all_regressions)} key(s) over budget "
              f"({total_checked} checked, {total_skipped} skipped)")
        return 1
    print(f"bench-budget: PASS — {total_checked} key(s) within budget "
          f"({total_skipped} skipped)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Pure-jnp correctness oracles for the Pallas kernels.

These are the reference semantics the kernels (and the Rust native
interpolator in ``rust/src/perfdb/query.rs``) must match. pytest +
hypothesis compare kernel vs ref across shapes/dtypes; the Rust unit tests
replicate the same closed-form cases (linear surfaces reproduced exactly,
corner clamping, degenerate axes).
"""

from __future__ import annotations

import jax.numpy as jnp


def interp_ref(grids, tids, coords):
    """Trilinear interpolation over packed grids — reference semantics.

    grids: f32[T, NX, NY, NZ]; tids: i32[Q]; coords: f32[Q, 3].
    Returns f32[Q].
    """
    nx, ny, nz = grids.shape[1], grids.shape[2], grids.shape[3]
    x = jnp.clip(coords[:, 0], 0.0, nx - 1.0)
    y = jnp.clip(coords[:, 1], 0.0, ny - 1.0)
    z = jnp.clip(coords[:, 2], 0.0, nz - 1.0)

    x0 = jnp.floor(x).astype(jnp.int32)
    y0 = jnp.floor(y).astype(jnp.int32)
    z0 = jnp.floor(z).astype(jnp.int32)
    x1 = jnp.minimum(x0 + 1, nx - 1)
    y1 = jnp.minimum(y0 + 1, ny - 1)
    z1 = jnp.minimum(z0 + 1, nz - 1)

    xd = x - x0
    yd = y - y0
    zd = z - z0

    def g(ix, iy, iz):
        return grids[tids, ix, iy, iz]

    c00 = g(x0, y0, z0) * (1 - xd) + g(x1, y0, z0) * xd
    c01 = g(x0, y0, z1) * (1 - xd) + g(x1, y0, z1) * xd
    c10 = g(x0, y1, z0) * (1 - xd) + g(x1, y1, z0) * xd
    c11 = g(x0, y1, z1) * (1 - xd) + g(x1, y1, z1) * xd

    c0 = c00 * (1 - yd) + c10 * yd
    c1 = c01 * (1 - yd) + c11 * yd
    return c0 * (1 - zd) + c1 * zd


def moe_powerlaw_ref(u, alpha, params):
    """Eq. (3)-(4) of the paper — reference semantics.

    u: f32[S, E]; alpha: f32[S]; params: f32[S, 3] = (x_min, x_max, T*K).
    Returns (loads f32[S, E], imbalance f32[S]).
    """
    e = u.shape[1]
    one_m = (1.0 - alpha)[:, None]
    lo = params[:, 0:1] ** one_m
    hi = params[:, 1:2] ** one_m
    x = ((hi - lo) * u + lo) ** (1.0 / one_m)
    w = x / jnp.sum(x, axis=1, keepdims=True)
    loads = w * params[:, 2:3]
    imb = jnp.max(loads, axis=1) / (params[:, 2] / float(e))
    return loads, imb

"""L1 Pallas kernel: batched trilinear interpolation over packed perf grids.

This is the innermost hot-spot of AIConfigurator's GETSTEPLATENCY: every
candidate serving configuration decomposes into operator queries
(GEMM / attention / communication / MoE), each of which is answered by
interpolating the operator's calibrated latency grid (paper §4.4,
"interpolation estimates latencies for intermediate configurations").

Layout
------
* ``grids``  : f32[T, NX, NY, NZ] — T packed lookup tables. Each table is a
  latency surface over three *normalized* axes; the axis transforms
  (log-spacing over M/N/K, batch, sequence length, message size, ...) are
  applied by the Rust coordinator before the query reaches this kernel, so
  coordinates arrive as fractional grid indices in ``[0, N-1]``.
* ``tids``   : i32[Q]    — table id per query.
* ``coords`` : f32[Q, 3] — fractional (x, y, z) grid coordinates.
* returns    : f32[Q]    — interpolated latency (microseconds).

Tables with a degenerate axis (e.g. 2-D attention surfaces stored with
NZ>1 but constant along z) are handled naturally: upper corner indices are
clamped to the axis bound, and the fractional weight of a clamped corner
collapses the interpolation to the lower corner.

TPU adaptation (§Hardware-Adaptation in DESIGN.md): the kernel is tiled
over the query axis — each program instance stages a block of
``block_q`` queries (tids + coords ≈ 16·block_q bytes) into VMEM while the
packed grids stay resident (T·NX·NY·NZ·4 B ≈ 1 MiB for the default
16×32×32×16 database, well inside the 16 MiB VMEM budget, so the
BlockSpec maps the full grid into every program). The 8-corner gather is
the bottleneck — a VPU/gather-bound kernel, not MXU — so block_q is chosen
to amortize grid residency across many queries. MUST run with
``interpret=True`` on CPU (Mosaic custom-calls cannot execute on the CPU
PJRT plugin).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 1024


def _interp_kernel(tids_ref, coords_ref, grids_ref, out_ref):
    """One query tile: gather 8 corners per query and blend trilinearly."""
    t = tids_ref[...]  # [Bq] i32
    c = coords_ref[...]  # [Bq, 3] f32
    g = grids_ref[...]  # [T, NX, NY, NZ] f32
    nx, ny, nz = g.shape[1], g.shape[2], g.shape[3]

    x = jnp.clip(c[:, 0], 0.0, float(nx - 1))
    y = jnp.clip(c[:, 1], 0.0, float(ny - 1))
    z = jnp.clip(c[:, 2], 0.0, float(nz - 1))

    x0 = jnp.floor(x).astype(jnp.int32)
    y0 = jnp.floor(y).astype(jnp.int32)
    z0 = jnp.floor(z).astype(jnp.int32)
    x1 = jnp.minimum(x0 + 1, nx - 1)
    y1 = jnp.minimum(y0 + 1, ny - 1)
    z1 = jnp.minimum(z0 + 1, nz - 1)

    xd = x - x0.astype(jnp.float32)
    yd = y - y0.astype(jnp.float32)
    zd = z - z0.astype(jnp.float32)

    # 8-corner gather (vectorized advanced indexing → gather in HLO).
    c000 = g[t, x0, y0, z0]
    c001 = g[t, x0, y0, z1]
    c010 = g[t, x0, y1, z0]
    c011 = g[t, x0, y1, z1]
    c100 = g[t, x1, y0, z0]
    c101 = g[t, x1, y0, z1]
    c110 = g[t, x1, y1, z0]
    c111 = g[t, x1, y1, z1]

    c00 = c000 * (1.0 - xd) + c100 * xd
    c01 = c001 * (1.0 - xd) + c101 * xd
    c10 = c010 * (1.0 - xd) + c110 * xd
    c11 = c011 * (1.0 - xd) + c111 * xd

    c0 = c00 * (1.0 - yd) + c10 * yd
    c1 = c01 * (1.0 - yd) + c11 * yd

    out_ref[...] = c0 * (1.0 - zd) + c1 * zd


@functools.partial(jax.jit, static_argnames=("block_q",))
def interp(grids, tids, coords, *, block_q: int = DEFAULT_BLOCK_Q):
    """Batched trilinear interpolation.

    Args:
      grids:  f32[T, NX, NY, NZ] packed latency tables.
      tids:   i32[Q] table id per query.
      coords: f32[Q, 3] fractional grid coordinates.
      block_q: queries per program instance (Q must be divisible).

    Returns:
      f32[Q] interpolated values.
    """
    q = tids.shape[0]
    if q % block_q != 0:
        raise ValueError(f"Q={q} must be a multiple of block_q={block_q}")
    t, nx, ny, nz = grids.shape
    return pl.pallas_call(
        _interp_kernel,
        grid=(q // block_q,),
        in_specs=[
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q, 3), lambda i: (i, 0)),
            pl.BlockSpec((t, nx, ny, nz), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.float32),
        interpret=True,
    )(tids, coords, grids)

"""L1 Pallas kernel: power-law MoE expert-load synthesis (paper §4.4.1).

Implements the controlled token-assignment procedure of Eq. (3)-(4):
inverse-transform sampling of per-expert load weights from a bounded
power-law, normalization to token counts, and the hot-expert tail factor
that determines grouped-GEMM latency in practice ("the tail latency caused
by the most heavily loaded expert ... determines overall throughput").

Layout
------
* ``u``      : f32[S, E] — uniform(0,1) samples, one row per scenario
  (the Rust coordinator owns the RNG so runs are reproducible).
* ``alpha``  : f32[S]    — skew per scenario (α≈0 uniform, α≈1.2 heavy
  tail). α = 1 is singular in Eq. (3); callers must nudge it away
  (the Rust side clamps to |α-1| ≥ 1e-3).
* ``params`` : f32[S, 3] — (x_min, x_max, T_total·K) per scenario.

Returns
-------
* ``loads``  : f32[S, E] — fractional token count per expert
  (integer rounding + residual redistribution happens in Rust, which
  needs exact totals; the float surface is what the latency model uses).
* ``imb``    : f32[S]    — tail factor: max_i N_i / (T_total·K / E), i.e.
  how much slower the hottest expert is than the balanced ideal.

Tiled over scenarios; each program stages a [block_s, E] tile into VMEM
(E=128, block_s=64 → 32 KiB). Pure VPU work (exp/log/divide), no MXU.
interpret=True for CPU-PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_S = 64


def _moe_kernel(u_ref, alpha_ref, params_ref, loads_ref, imb_ref):
    u = u_ref[...]  # [Bs, E]
    a = alpha_ref[...]  # [Bs]
    p = params_ref[...]  # [Bs, 3]
    e = u.shape[1]

    one_m = (1.0 - a)[:, None]  # [Bs, 1]
    x_min = p[:, 0:1]
    x_max = p[:, 1:2]
    total = p[:, 2:3]  # T_total * K

    # Eq. (3): x_i = [(x_max^{1-a} - x_min^{1-a}) U + x_min^{1-a}]^{1/(1-a)}
    lo = x_min**one_m
    hi = x_max**one_m
    x = ((hi - lo) * u + lo) ** (1.0 / one_m)

    # Eq. (4): normalize to token counts (float; rounding done by caller).
    w = x / jnp.sum(x, axis=1, keepdims=True)
    loads = w * total

    loads_ref[...] = loads
    imb_ref[...] = jnp.max(loads, axis=1) / (total[:, 0] / float(e))


@functools.partial(jax.jit, static_argnames=("block_s",))
def moe_powerlaw(u, alpha, params, *, block_s: int = DEFAULT_BLOCK_S):
    """Sample power-law expert loads for a batch of scenarios.

    Args:
      u:      f32[S, E] uniform samples.
      alpha:  f32[S] power-law skew (must not be exactly 1).
      params: f32[S, 3] columns (x_min, x_max, T_total*K).
      block_s: scenarios per program instance (S must be divisible).

    Returns:
      (loads f32[S, E], imbalance f32[S]).
    """
    s, e = u.shape
    if s % block_s != 0:
        raise ValueError(f"S={s} must be a multiple of block_s={block_s}")
    return pl.pallas_call(
        _moe_kernel,
        grid=(s // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, e), lambda i: (i, 0)),
            pl.BlockSpec((block_s,), lambda i: (i,)),
            pl.BlockSpec((block_s, 3), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_s, e), lambda i: (i, 0)),
            pl.BlockSpec((block_s,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, e), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        interpret=True,
    )(u, alpha, params)

"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. Lower with ``return_tuple=True``
and unwrap with ``to_tuple1()``/``to_tupleN`` on the Rust side.
See /opt/xla-example/load_hlo and its README gotchas.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Emits:  interp.hlo.txt, moe_powerlaw.hlo.txt, manifest.json
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all() -> dict:
    """Lower every exported entry point; returns {name: hlo_text}."""
    out = {}
    out["interp"] = to_hlo_text(
        jax.jit(model.latency_eval).lower(*model.latency_eval_specs())
    )
    # Small-batch variant for candidate-evaluation step sweeps (§Perf).
    out["interp_small"] = to_hlo_text(
        jax.jit(model.latency_eval).lower(
            *model.latency_eval_specs(model.QUERY_BATCH_SMALL)
        )
    )
    out["moe_powerlaw"] = to_hlo_text(
        jax.jit(model.moe_load_eval).lower(*model.moe_load_eval_specs())
    )
    return out


def manifest() -> dict:
    """Shape contract consumed by rust/src/runtime (asserted at load)."""
    return {
        "interp": {
            "num_tables": model.NUM_TABLES,
            "grid": [model.GRID_NX, model.GRID_NY, model.GRID_NZ],
            "query_batch": model.QUERY_BATCH,
            "query_batch_small": model.QUERY_BATCH_SMALL,
            "inputs": ["grids", "tids", "coords"],
            "outputs": ["lat"],
        },
        "moe_powerlaw": {
            "scenarios": model.MOE_SCENARIOS,
            "experts": model.MOE_EXPERTS,
            "inputs": ["u", "alpha", "params"],
            "outputs": ["loads", "imbalance"],
        },
    }


def main() -> None:
    p = argparse.ArgumentParser(description="AOT-lower AIConfigurator kernels")
    p.add_argument("--out-dir", default="../artifacts")
    # Back-compat with the scaffold Makefile's single-file invocation.
    p.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = p.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    for name, text in lower_all().items():
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars -> {path}")

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest(), f, indent=2)
    print(f"wrote manifest -> {mpath}")


if __name__ == "__main__":
    main()

"""L2: the JAX compute graph AOT-compiled for the Rust coordinator.

AIConfigurator's hot path is not a neural network forward pass — it is the
batched evaluation of operator-latency queries against the calibrated
performance database (paper §4.3-4.4), plus the power-law MoE load model
(§4.4.1). Both are expressed here as jittable JAX functions that call the
L1 Pallas kernels, and are lowered once by ``aot.py`` to HLO text that the
Rust runtime loads via PJRT. Python never runs on the request path.

Exported entry points (fixed AOT shapes; the Rust side pads batches):

* ``latency_eval(grids, tids, coords)``        -> (lat[Q],)
* ``moe_load_eval(u, alpha, params)``          -> (loads[S,E], imb[S])

Shape constants here are the single source of truth; ``aot.py`` writes
them to ``artifacts/manifest.json`` and the Rust runtime asserts against
them at load time (rust/src/runtime/mod.rs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.interp import interp
from compile.kernels.moe_powerlaw import moe_powerlaw

# --- AOT shape contract (mirrored in rust/src/runtime/mod.rs) -----------
NUM_TABLES = 16  # packed operator tables
GRID_NX = 32
GRID_NY = 32
GRID_NZ = 16
QUERY_BATCH = 8192  # operator queries per PJRT execution (bulk variant)
# Small-batch variant: candidate evaluation issues dozens-to-hundreds of
# queries per step sweep; padding those to 8192 wastes ~30x gather work
# (§Perf L1/L2 iteration 1 in EXPERIMENTS.md). The runtime picks the
# variant by batch size.
QUERY_BATCH_SMALL = 256

MOE_SCENARIOS = 256
MOE_EXPERTS = 128


def latency_eval(grids, tids, coords):
    """Batched operator-latency lookup: trilinear interpolation kernel.

    A single fused HLO module: coordinate clamping, 8-corner gather and
    blend all lower into one program — no host round-trips between
    operators of the same candidate configuration. The Pallas query tile
    shrinks with the batch so the small AOT variant stays single-tile.
    """
    block_q = min(tids.shape[0], 1024)
    lat = interp(grids, tids, coords, block_q=block_q)
    return (lat,)


def moe_load_eval(u, alpha, params):
    """Batched power-law expert-load synthesis (Eq. 3-4 + tail factor)."""
    loads, imb = moe_powerlaw(u, alpha, params)
    return (loads, imb)


def latency_eval_specs(batch: int = QUERY_BATCH):
    """ShapeDtypeStructs for AOT lowering of ``latency_eval``."""
    return (
        jax.ShapeDtypeStruct((NUM_TABLES, GRID_NX, GRID_NY, GRID_NZ), jnp.float32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch, 3), jnp.float32),
    )


def moe_load_eval_specs():
    """ShapeDtypeStructs for AOT lowering of ``moe_load_eval``."""
    return (
        jax.ShapeDtypeStruct((MOE_SCENARIOS, MOE_EXPERTS), jnp.float32),
        jax.ShapeDtypeStruct((MOE_SCENARIOS,), jnp.float32),
        jax.ShapeDtypeStruct((MOE_SCENARIOS, 3), jnp.float32),
    )

"""L2/AOT: lowering produces well-formed HLO text with the contract shapes.

These tests guard the interchange format the Rust runtime depends on:
entry layout shapes, tuple return, and manifest consistency.
"""

from __future__ import annotations

import json
import os

import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def lowered():
    return aot.lower_all()


def test_interp_entry_layout(lowered):
    hlo = lowered["interp"]
    assert hlo.startswith("HloModule")
    t, nx, ny, nz = model.NUM_TABLES, model.GRID_NX, model.GRID_NY, model.GRID_NZ
    q = model.QUERY_BATCH
    assert f"f32[{t},{nx},{ny},{nz}]" in hlo
    assert f"s32[{q}]" in hlo
    assert f"f32[{q},3]" in hlo
    # return_tuple=True → tuple-typed root.
    assert f"->(f32[{q}]" in hlo


def test_moe_entry_layout(lowered):
    hlo = lowered["moe_powerlaw"]
    s, e = model.MOE_SCENARIOS, model.MOE_EXPERTS
    assert f"f32[{s},{e}]" in hlo
    assert f"f32[{s},3]" in hlo
    assert f"->(f32[{s},{e}]" in hlo


def test_no_custom_calls(lowered):
    """interpret=True must lower to plain HLO — no Mosaic custom-calls,
    which the CPU PJRT client cannot execute."""
    for name, hlo in lowered.items():
        assert "custom-call" not in hlo, f"{name} contains a custom-call"


def test_manifest_matches_model():
    m = aot.manifest()
    assert m["interp"]["num_tables"] == model.NUM_TABLES
    assert m["interp"]["grid"] == [model.GRID_NX, model.GRID_NY, model.GRID_NZ]
    assert m["interp"]["query_batch"] == model.QUERY_BATCH
    assert m["moe_powerlaw"]["experts"] == model.MOE_EXPERTS


def test_artifacts_on_disk_if_built():
    """If `make artifacts` has run, the files must agree with the manifest."""
    mpath = os.path.join(ART, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    with open(mpath) as f:
        m = json.load(f)
    assert m == aot.manifest()
    for name in ("interp", "moe_powerlaw"):
        p = os.path.join(ART, f"{name}.hlo.txt")
        assert os.path.exists(p)
        with open(p) as f:
            assert f.read(9) == "HloModule"

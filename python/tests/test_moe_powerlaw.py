"""L1 correctness: Pallas power-law MoE kernel vs oracle + Eq.(3-4) laws.

Checks: allclose vs ref across shapes; loads sum to T_total*K; alpha→0
approaches uniform routing; imbalance grows monotonically with alpha
(paper Fig. 5); alpha≈1.2 concentrates ~70% of load on ~20% of experts
(the Qwen3-235B observation motivating §4.4.1).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.moe_powerlaw import moe_powerlaw
from compile.kernels.ref import moe_powerlaw_ref


def _run(u, alpha, params, block_s=None):
    s = u.shape[0]
    bs = block_s or s
    return moe_powerlaw(jnp.array(u), jnp.array(alpha), jnp.array(params), block_s=bs)


def _mk(rng, s, e, alphas=None):
    u = (rng.random((s, e)) * 0.998 + 1e-3).astype(np.float32)
    alpha = (
        alphas
        if alphas is not None
        else rng.choice([0.05, 0.3, 0.6, 0.9, 1.1, 1.2, 1.4], s)
    ).astype(np.float32)
    params = np.tile(np.array([1.0, 100.0, 8192.0], dtype=np.float32), (s, 1))
    return u, alpha, params


@settings(max_examples=40, deadline=None)
@given(
    s_blocks=st.integers(1, 4),
    block_s=st.sampled_from([2, 4, 8]),
    e=st.sampled_from([8, 32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref(s_blocks, block_s, e, seed):
    rng = np.random.default_rng(seed)
    s = s_blocks * block_s
    u, alpha, params = _mk(rng, s, e)
    loads, imb = _run(u, alpha, params, block_s)
    rl, ri = moe_powerlaw_ref(jnp.array(u), jnp.array(alpha), jnp.array(params))
    np.testing.assert_allclose(np.asarray(loads), np.asarray(rl), rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(np.asarray(imb), np.asarray(ri), rtol=1e-4, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_loads_sum_to_total(seed):
    rng = np.random.default_rng(seed)
    u, alpha, params = _mk(rng, 8, 64)
    loads, _ = _run(u, alpha, params)
    np.testing.assert_allclose(
        np.asarray(jnp.sum(loads, axis=1)), params[:, 2], rtol=1e-4
    )


def test_alpha_zero_near_uniform():
    rng = np.random.default_rng(1)
    s, e = 4, 128
    u = (rng.random((s, e)) * 0.998 + 1e-3).astype(np.float32)
    alpha = np.full(s, 1e-3, dtype=np.float32)
    params = np.tile(np.array([1.0, 1.0001, 4096.0], dtype=np.float32), (s, 1))
    loads, imb = _run(u, alpha, params)
    # With x_min ~= x_max the weights are ~equal regardless of U.
    np.testing.assert_allclose(np.asarray(imb), 1.0, rtol=1e-3)


def test_imbalance_monotone_in_alpha():
    rng = np.random.default_rng(2)
    e = 128
    u = (rng.random((1, e)) * 0.998 + 1e-3).astype(np.float32)
    alphas = [0.05, 0.4, 0.8, 1.2, 1.5]
    imbs = []
    for a in alphas:
        _, imb = _run(u, np.array([a], np.float32),
                      np.tile(np.array([1.0, 100.0, 8192.0], np.float32), (1, 1)))
        imbs.append(float(imb[0]))
    assert all(b > a for a, b in zip(imbs, imbs[1:])), imbs


def test_heavy_tail_top20_share():
    """alpha≈1.2 → top-20% experts handle the majority (~70%) of tokens."""
    rng = np.random.default_rng(3)
    s, e = 16, 128
    u = (rng.random((s, e)) * 0.998 + 1e-3).astype(np.float32)
    alpha = np.full(s, 1.2, dtype=np.float32)
    params = np.tile(np.array([1.0, 100.0, 65536.0], dtype=np.float32), (s, 1))
    loads, _ = _run(u, alpha, params)
    loads = np.asarray(loads)
    top = int(0.2 * e)
    share = np.sort(loads, axis=1)[:, -top:].sum(axis=1) / loads.sum(axis=1)
    assert share.mean() > 0.5, share.mean()
    # and far from uniform (uniform would be exactly 0.2)
    assert share.mean() > 0.45


def test_alpha_below_and_above_one_consistent():
    """Eq.(3) is well-defined on both sides of the α=1 singularity.

    f32 precision collapses as |1-α| → 0, so the Rust caller clamps
    |α-1| >= 0.02; we verify continuity at that guard band.
    """
    rng = np.random.default_rng(4)
    row = (rng.random((1, 64)) * 0.998 + 1e-3).astype(np.float32)
    u = np.vstack([row, row])  # identical draws — isolate the α effect
    params = np.tile(np.array([1.0, 100.0, 4096.0], np.float32), (2, 1))
    la, ia = _run(u, np.array([0.98, 1.02], np.float32), params)
    assert np.all(np.isfinite(np.asarray(la)))
    # α just below vs just above 1 should give nearby imbalance.
    assert abs(float(ia[0]) - float(ia[1])) / float(ia[0]) < 0.2

"""L1 correctness: Pallas interp kernel vs pure-jnp oracle.

Hypothesis sweeps grid shapes, table counts, block sizes and coordinate
ranges (including out-of-range coordinates, which must clamp) and asserts
allclose against ``ref.interp_ref``. Closed-form cases pin down the
semantics independently of the oracle.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.interp import interp
from compile.kernels.ref import interp_ref


def _mk(rng, t, nx, ny, nz, q, lo=-2.0, scale=1.3):
    grids = (rng.random((t, nx, ny, nz)) * 1000.0).astype(np.float32)
    tids = rng.integers(0, t, q).astype(np.int32)
    # Coordinates deliberately overshoot the grid on both sides.
    coords = (
        rng.random((q, 3)) * (np.array([nx, ny, nz]) * scale) + lo
    ).astype(np.float32)
    return grids, tids, coords


@settings(max_examples=40, deadline=None)
@given(
    t=st.integers(1, 8),
    nx=st.integers(2, 16),
    ny=st.integers(2, 16),
    nz=st.integers(1, 8),
    logq=st.integers(0, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref(t, nx, ny, nz, logq, seed):
    rng = np.random.default_rng(seed)
    block_q = 4 * 2**logq
    q = block_q * int(rng.integers(1, 5))
    grids, tids, coords = _mk(rng, t, nx, ny, nz, q)
    got = interp(jnp.array(grids), jnp.array(tids), jnp.array(coords), block_q=block_q)
    want = interp_ref(jnp.array(grids), jnp.array(tids), jnp.array(coords))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_linear_surface_exact(seed):
    """Trilinear interpolation reproduces a trilinear function exactly."""
    rng = np.random.default_rng(seed)
    nx, ny, nz = 8, 6, 4
    a, b, c, d = rng.random(4).astype(np.float32) * 10
    ix, iy, iz = np.meshgrid(
        np.arange(nx), np.arange(ny), np.arange(nz), indexing="ij"
    )
    grid = (a * ix + b * iy + c * iz + d).astype(np.float32)[None]
    q = 64
    coords = (rng.random((q, 3)) * np.array([nx - 1, ny - 1, nz - 1])).astype(
        np.float32
    )
    tids = np.zeros(q, dtype=np.int32)
    got = np.asarray(
        interp(jnp.array(grid), jnp.array(tids), jnp.array(coords), block_q=16)
    )
    want = a * coords[:, 0] + b * coords[:, 1] + c * coords[:, 2] + d
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


def test_grid_points_exact():
    """Queries exactly on grid points return the stored values."""
    rng = np.random.default_rng(7)
    grids = (rng.random((3, 5, 5, 3)) * 100).astype(np.float32)
    pts = [(t, x, y, z) for t in range(3) for x in range(5) for y in range(5) for z in range(3)]
    rng.shuffle(pts)
    pts = pts[:32]
    tids = np.array([p[0] for p in pts], dtype=np.int32)
    coords = np.array([p[1:] for p in pts], dtype=np.float32)
    got = np.asarray(interp(jnp.array(grids), jnp.array(tids), jnp.array(coords), block_q=32))
    want = np.array([grids[p] for p in pts])
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-4)


def test_clamping():
    """Out-of-range coordinates clamp to the boundary surface."""
    grids = np.arange(2 * 4 * 4 * 2, dtype=np.float32).reshape(2, 4, 4, 2)
    tids = np.array([0, 0, 1, 1], dtype=np.int32)
    coords = np.array(
        [[-5.0, -5.0, -5.0], [99.0, 99.0, 99.0], [-1.0, 2.0, 0.5], [3.0, 99.0, 1.0]],
        dtype=np.float32,
    )
    got = np.asarray(interp(jnp.array(grids), jnp.array(tids), jnp.array(coords), block_q=4))
    assert got[0] == grids[0, 0, 0, 0]
    assert got[1] == grids[0, 3, 3, 1]
    assert got[2] == pytest.approx((grids[1, 0, 2, 0] + grids[1, 0, 2, 1]) / 2, rel=1e-5)
    assert got[3] == grids[1, 3, 3, 1]


def test_degenerate_z_axis():
    """NZ=1 tables (2-D surfaces) interpolate over x,y only."""
    rng = np.random.default_rng(3)
    grids = (rng.random((1, 6, 6, 1)) * 10).astype(np.float32)
    tids = np.zeros(8, dtype=np.int32)
    coords = np.stack(
        [
            rng.random(8).astype(np.float32) * 5,
            rng.random(8).astype(np.float32) * 5,
            rng.random(8).astype(np.float32) * 3,  # z ignored after clamp
        ],
        axis=1,
    )
    got = np.asarray(interp(jnp.array(grids), jnp.array(tids), jnp.array(coords), block_q=8))
    coords0 = coords.copy()
    coords0[:, 2] = 0.0
    want = np.asarray(interp_ref(jnp.array(grids), jnp.array(tids), jnp.array(coords0)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_bad_block_raises():
    grids = np.zeros((1, 2, 2, 1), dtype=np.float32)
    with pytest.raises(ValueError):
        interp(
            jnp.array(grids),
            jnp.zeros(10, jnp.int32),
            jnp.zeros((10, 3), jnp.float32),
            block_q=16,
        )

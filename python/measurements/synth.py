#!/usr/bin/env python3
"""Generate the committed synthetic kernel-measurement set.

Writes ``artifacts/measurements/h100-sxm/<table>.json`` in the format of
``rust/src/perfdb/measure.rs``: per-table latencies "measured" at grid
coordinates of the 16x32x32x16 database geometry, produced by a Python
mirror of the synthetic-silicon latency model (``rust/src/silicon``)
perturbed by a fixed-seed multiplicative bias + lognormal noise model
(the same default bias table as ``measure::default_bias``).

The committed values are self-consistent ground truth for the
calibration pipeline: the ``calibrate`` CLI fits log-space corrections
of the *Rust-profiled* analytic fill against them, and CI asserts the
fit reduces per-table MAPE. Any small drift between this mirror and the
Rust silicon just becomes part of the miscalibration the fit absorbs —
the committed set is what a real measurement campaign would be: an
external, imperfect observation of the hardware.

Regenerate with:  python3 python/measurements/synth.py
(deterministic; a clean ``git diff`` confirms reproducibility)
"""

import json
import math
import os

SEED = 20260727
SIGMA = 0.03
POINTS_PER_TABLE = 48
REPEATS = 3

CONTEXT = {
    "gpu": "h100-sxm",
    "model": "qwen3-32b",
    "framework": "trtllm",
    "kv_dtype": "fp8",
}

# --- hardware/mod.rs: h100_sxm + ClusterSpec::new(gpu, 8, 1) -------------
MEM_BW_GBS = 3350.0
FP16_TFLOPS = 989.0
FP8_TFLOPS = 1979.0
NVLINK_GBS = 450.0
SM_COUNT = 132
LAUNCH_US = 3.0
GPUS_PER_NODE = 8
IB_GBS = 50.0
IB_LATENCY_US = 8.0
NVLINK_LATENCY_US = 2.0

# --- frameworks/trtllm.rs profile ----------------------------------------
GEMM_EFF = 0.92
ATTN_PREFILL_EFF = 0.90
ATTN_DECODE_EFF = 0.88

# --- models/presets.rs qwen3_32b -----------------------------------------
MODEL_HEADS = 64
MODEL_KV_HEADS = 8
MODEL_HEAD_DIM = 128
KV_DTYPE_BYTES = 1.0  # fp8

# --- perfdb/tables.rs grid geometry --------------------------------------
NX, NY, NZ = 32, 32, 16

# measure::default_bias — (scale factor, x-tilt) ground truth per table.
BIAS = {
    "gemm_fp16": (1.28, 0.10),
    "gemm_fp8": (1.28, 0.10),
    "attn_prefill": (1.17, 0.08),
    "attn_decode": (1.22, 0.06),
    "allreduce": (1.40, 0.05),
    "p2p": (1.26, 0.0),
}

M64 = (1 << 64) - 1


class Rng:
    """Exact port of util/rng.rs (splitmix64-seeded xoshiro256**)."""

    def __init__(self, seed):
        sm = seed & M64
        s = []
        for _ in range(4):
            sm = (sm + 0x9E3779B97F4A7C15) & M64
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
            s.append(z ^ (z >> 31))
        self.s = s
        self.spare = None

    def next_u64(self):
        s = self.s
        r = ((self._rotl((s[1] * 5) & M64, 7) * 9)) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = self._rotl(s[3], 45)
        return r

    @staticmethod
    def _rotl(x, k):
        return ((x << k) | (x >> (64 - k))) & M64

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def f64_open(self):
        return ((self.next_u64() >> 11) + 0.5) * (1.0 / (1 << 53))

    def below(self, n):
        return (self.next_u64() * n) >> 64

    def normal(self):
        if self.spare is not None:
            v, self.spare = self.spare, None
            return v
        u1 = self.f64_open()
        u2 = self.f64()
        r = math.sqrt(-2.0 * math.log(u1))
        a = 2.0 * math.pi * u2
        self.spare = r * math.sin(a)
        return r * math.cos(a)

    def noise(self, sigma):
        return math.exp(sigma * self.normal() - 0.5 * sigma * sigma)


# --- axis mapping (perfdb/tables.rs) -------------------------------------
def log_axis(lo, hi, n):
    def value(i):
        l, h = math.log2(lo), math.log2(hi)
        return 2.0 ** (l + (h - l) * i / (n - 1))

    return value


def lin_axis(lo, hi, n):
    def value(i):
        return lo + (hi - lo) * i / (n - 1)

    return value


def const_axis(v):
    return lambda i: v


# (x, y, z) axis value functions + degenerate-z flag per committed table.
TABLES = {
    "gemm_fp16": (log_axis(1.0, 262144.0, NX), log_axis(64.0, 262144.0, NY),
                  log_axis(64.0, 32768.0, NZ), False),
    "gemm_fp8": (log_axis(1.0, 262144.0, NX), log_axis(64.0, 262144.0, NY),
                 log_axis(64.0, 32768.0, NZ), False),
    "attn_prefill": (log_axis(1.0, 16384.0, NX), log_axis(16.0, 131072.0, NY),
                     log_axis(1.0, 128.0, NZ), False),
    "attn_decode": (log_axis(1.0, 512.0, NX), log_axis(16.0, 131072.0, NY),
                    log_axis(1.0, 128.0, NZ), False),
    "allreduce": (log_axis(256.0, 1.074e9, NX), log_axis(2.0, 64.0, NY),
                  const_axis(0.0), True),
    "p2p": (log_axis(256.0, 1.074e9, NX), lin_axis(0.0, 1.0, NY),
            const_axis(0.0), True),
}


# --- silicon mirror (rust/src/silicon) ------------------------------------
def clamp(v, lo, hi):
    return max(lo, min(hi, v))


def gemm_us(m, n, k, dtype_bytes, tflops):
    m, n, k = max(m, 1), max(n, 1), max(k, 1)
    flops = 2.0 * m * n * k
    tiles_m = -(-m // 128)
    tiles_n = -(-n // 128)
    tiles = tiles_m * tiles_n
    slots = SM_COUNT
    waves = -(-tiles // slots)
    wave_util = tiles / (waves * slots)
    fill_m = clamp(m / (tiles_m * 128.0), 0.05, 1.0)
    occ = 0.6 if m < 16 else 1.0
    util = clamp(wave_util * (0.35 + 0.65 * fill_m) * occ, 0.02, 1.0)
    t_compute = flops / (tflops * 1e12 * GEMM_EFF * util) * 1e6
    w_bytes = n * k * dtype_bytes
    act_bytes = (m * k + m * n) * 2.0
    t_mem = (w_bytes + act_bytes) / (MEM_BW_GBS * 1e3) / GEMM_EFF
    return max(t_compute, t_mem) + LAUNCH_US


def attn_prefill_us(q_tokens, kv_len, heads, head_dim, causal_frac):
    q, kv = max(q_tokens, 1), max(kv_len, 1)
    flops = 4.0 * heads * q * kv * head_dim * causal_frac
    seq_fill = clamp(kv / 1024.0, 0.15, 1.0)
    head_fill = clamp(heads / 8.0, 0.5, 1.0)
    eff = ATTN_PREFILL_EFF * seq_fill**0.35 * head_fill**0.2
    t_compute = flops / (FP16_TFLOPS * 1e12 * eff) * 1e6
    io_bytes = (2 * q_tokens + 2 * kv_len) * heads * head_dim * 2.0
    t_mem = io_bytes / (MEM_BW_GBS * 1e3)
    return max(t_compute, t_mem) + LAUNCH_US


def attn_decode_us(batch, kv_len, heads, head_dim, kv_token_bytes):
    b, kv = max(batch, 1), max(kv_len, 1)
    bytes_ = b * kv * kv_token_bytes
    ctas = max(b * heads / 8.0, 1.0)
    bw_fill = clamp(ctas / SM_COUNT, 0.25, 1.0)
    t_mem = bytes_ / (MEM_BW_GBS * 1e3 * ATTN_DECODE_EFF * bw_fill)
    flops = 4.0 * b * heads * head_dim * kv
    t_compute = flops / (FP16_TFLOPS * 1e12 * 0.25) * 1e6
    return max(t_mem, t_compute) + LAUNCH_US


def kv_bytes_for_heads(heads):
    # builder.rs::kv_bytes_for_heads for a GQA model at kv dtype fp8.
    frac = min(heads / MODEL_HEADS, 1.0)
    kv_heads = max(MODEL_KV_HEADS * frac, 1.0)
    return 2.0 * kv_heads * MODEL_HEAD_DIM * KV_DTYPE_BYTES


def allreduce_us(nbytes, gpus):
    if gpus <= 1:
        return 0.0
    cross = gpus > GPUS_PER_NODE
    bw = (IB_GBS if cross else NVLINK_GBS) * 1e3 * 0.80
    lat = IB_LATENCY_US if cross else NVLINK_LATENCY_US
    g = float(gpus)
    t = 2.0 * (g - 1.0) / g * nbytes / bw + 2.0 * (g - 1.0) * lat
    if cross:
        t += 0.5 * allreduce_us(nbytes, min(GPUS_PER_NODE, gpus))
    return t


def p2p_us(nbytes, cross_node):
    bw = (IB_GBS if cross_node else NVLINK_GBS) * 1e3 * 0.9
    lat = IB_LATENCY_US if cross_node else NVLINK_LATENCY_US
    return lat + nbytes / bw


def snap_pow2(v):
    return max(int(round(2.0 ** round(math.log2(max(v, 2.0))))), 2)


def silicon_us(table, x, y, z):
    """op_for_point + Silicon::op_latency_us for the committed tables."""
    if table == "gemm_fp16":
        return gemm_us(round(x), round(y), round(z), 2.0, FP16_TFLOPS)
    if table == "gemm_fp8":
        return gemm_us(round(x), round(y), round(z), 1.0, FP8_TFLOPS)
    if table == "attn_prefill":
        q, kv = max(round(x), 1), max(round(y), 1)
        causal = 0.5 if kv <= q else 1.0
        return attn_prefill_us(q, kv, max(round(z), 1), MODEL_HEAD_DIM, causal)
    if table == "attn_decode":
        heads = max(round(z), 1)
        return attn_decode_us(max(round(x), 1), max(round(y), 1), heads,
                              MODEL_HEAD_DIM, kv_bytes_for_heads(heads))
    if table == "allreduce":
        return allreduce_us(x, snap_pow2(y))
    if table == "p2p":
        return p2p_us(x, y >= 0.5)
    raise ValueError(table)


def main():
    out_dir = os.path.join(os.path.dirname(__file__), "..", "..",
                           "artifacts", "measurements", CONTEXT["gpu"])
    out_dir = os.path.normpath(out_dir)
    os.makedirs(out_dir, exist_ok=True)
    rng = Rng(SEED)
    for table in sorted(TABLES):
        xv, yv, zv, degenerate_z = TABLES[table]
        factor, tilt = BIAS[table]
        cells = []
        attempts = 0
        while len(cells) < POINTS_PER_TABLE and attempts < POINTS_PER_TABLE * 20:
            attempts += 1
            c = (rng.below(NX), rng.below(NY), 0 if degenerate_z else rng.below(NZ))
            if c not in cells:
                cells.append(c)
        entries = []
        for ix, iy, iz in cells:
            x, y, z = xv(ix), yv(iy), zv(iz)
            truth = silicon_us(table, x, y, z)
            corrected = truth * factor * math.exp(tilt * ix / (NX - 1))
            draws = sorted(corrected * rng.noise(SIGMA) for _ in range(REPEATS))
            entries.append({"x": x, "y": y, "z": z,
                            "us": draws[REPEATS // 2], "n": REPEATS})
        doc = {
            "version": 1,
            "table": table,
            "gpu": CONTEXT["gpu"],
            "model": CONTEXT["model"],
            "framework": CONTEXT["framework"],
            "kv_dtype": CONTEXT["kv_dtype"],
            "generator": f"python/measurements/synth.py seed={SEED} "
                         f"sigma={SIGMA} bias={factor}x+tilt{tilt}",
            "entries": entries,
        }
        path = os.path.join(out_dir, f"{table}.json")
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"wrote {path} ({len(entries)} points, bias x{factor})")


if __name__ == "__main__":
    main()

# AIConfigurator reproduction — top-level developer targets.
#
#   make verify     tier-1 gate: cargo build --release && cargo test -q
#   make gen-smoke  generator smoke gate (all backends emit resolved flags)
#   make artifacts-validate  schema-check every committed JSON artifact
#   make calibrate-smoke     fit the committed measurements end-to-end and
#                            assert post-fit MAPE < pre-fit MAPE per table
#   make measurements        regenerate artifacts/measurements (python)
#   make topo-smoke topology gate: every fabric preset's cost tables +
#                   a fabric-aware search end-to-end (mirrors CI)
#   make service-smoke  service pipeline gate: TCP protocol tests + the
#                   in-process coalescing/shedding/LRU load tests
#   make validate-smoke  fleet-replay gate: plan against the committed
#                   trace spec, replay it benign (optimism gap <= 10%)
#                   and injected (failures degrade gracefully)
#   make replan-smoke  differential-replan gate: apply every committed
#                   delta scenario (artifacts/deltas/) with --check-equal,
#                   asserting the incremental replan is bit-identical to
#                   a from-scratch plan of the patched inputs while
#                   re-pricing strictly fewer engine configs
#   make trace-smoke  tracing/explain gate: run search --trace-out and
#                   plan --explain end-to-end, then schema-check the
#                   Chrome trace-event exports (tests/artifacts.rs scans
#                   rust/target/trace-smoke/)
#   make bench      search-engine benches (table1_search + sweep)
#   make bench-plan capacity-planner bench (writes BENCH_plan.json)
#   make bench-topo topology bench (writes BENCH_topology.json)
#   make bench-service  closed-loop service bench (writes BENCH_service.json)
#   make bench-validate  fleet-replay bench (writes BENCH_validate.json)
#   make bench-replan  differential-replan bench (writes BENCH_replan.json)
#   make bench-trace  tracing-overhead bench (writes BENCH_trace.json;
#                   the artifact gate enforces <= 5% median regression)
#   make bench-all  every bench target
#   make bench-budget  perf-budget gate: snapshot the committed
#                   BENCH_plan/BENCH_topology baselines, re-run the
#                   sweep/planner/topology benches, schema-check the
#                   rewritten artifacts and fail if any *_ms_median
#                   regressed more than 15% (null baselines skip
#                   loudly — the gate arms once reference medians are
#                   committed)
#   make artifacts  AOT-lower the Pallas kernels to HLO (needs jax; the
#                   Rust side degrades gracefully when absent)
#   make fmt/clippy lint helpers mirroring CI (clippy is enforced in CI)

RUST_DIR := rust
PYTHON   ?= python3

.PHONY: verify build test gen-smoke artifacts-validate calibrate-smoke topo-smoke \
        service-smoke validate-smoke replan-smoke trace-smoke measurements bench \
        bench-plan bench-topo bench-service bench-validate bench-replan bench-trace \
        bench-all bench-budget artifacts fmt clippy clean

verify:
	cd $(RUST_DIR) && cargo build --release && cargo test -q

gen-smoke:
	cd $(RUST_DIR) && cargo test --test gen_smoke -- --nocapture

artifacts-validate:
	cd $(RUST_DIR) && cargo test --test artifacts -- --nocapture

calibrate-smoke:
	cd $(RUST_DIR) && cargo run --release -- calibrate \
		--model qwen3-32b --gpu h100 --framework trtllm \
		--measurements ../artifacts/measurements \
		--out target/calibration/h100-sxm.json \
		--report target/calibration/fidelity.json \
		--check-improves
	cd $(RUST_DIR) && cargo run --release -- search \
		--model qwen3-32b --gpu h100 --framework trtllm \
		--isl 4000 --osl 500 --ttft 2000 --speed 10 \
		--calibration target/calibration/h100-sxm.json

topo-smoke:
	cd $(RUST_DIR) && cargo run --release -- topo --fabric all --gpu h100 --nodes 4
	cd $(RUST_DIR) && cargo run --release -- search \
		--model qwen3-32b --gpu gb200-nvl72 --fabric gb200-nvl72 \
		--gpus-per-node 4 --nodes 4 \
		--isl 4000 --osl 500 --ttft 2000 --speed 10
	cd $(RUST_DIR) && cargo run --release -- search \
		--model qwen3-32b --gpu h100 --fabric hgx-h100 --nodes 2 \
		--isl 2048 --osl 256

service-smoke:
	cd $(RUST_DIR) && cargo test --test service --test service_load -- --nocapture

validate-smoke:
	cd $(RUST_DIR) && cargo run --release -- validate \
		--model llama3.1-8b --fleet h100 --framework trtllm \
		--isl 256 --osl 32 --ttft 5000 --speed 2 \
		--trace-spec ../artifacts/traces/diurnal-smoke.json \
		--out target/validate/benign.json \
		--check-gap 0.10
	cd $(RUST_DIR) && cargo run --release -- validate \
		--model llama3.1-8b --fleet h100 --framework trtllm \
		--isl 256 --osl 32 --ttft 5000 --speed 2 \
		--trace-spec ../artifacts/traces/diurnal-smoke.json \
		--scale-lag 30 --failure-rate 50 --restart 30 \
		--out target/validate/injected.json

replan-smoke:
	cd $(RUST_DIR) && cargo run --release -- replan \
		--model llama3.1-8b --fleet h100,a100 --framework trtllm \
		--isl 256 --osl 32 --ttft 5000 --speed 2 \
		--traffic diurnal --peak-qps 80 --trough-qps 4 --windows 12 \
		--delta ../artifacts/deltas/reprice-smoke.json \
		--out target/replan/reprice.json --check-equal
	cd $(RUST_DIR) && cargo run --release -- replan \
		--model llama3.1-8b --fleet h100,a100 --framework trtllm \
		--isl 256 --osl 32 --ttft 5000 --speed 2 \
		--traffic diurnal --peak-qps 80 --trough-qps 4 --windows 12 \
		--delta ../artifacts/deltas/window-surge-smoke.json \
		--out target/replan/window-surge.json --check-equal
	cd $(RUST_DIR) && cargo run --release -- replan \
		--model llama3.1-8b --fleet h100,a100 --framework trtllm \
		--isl 256 --osl 32 --ttft 5000 --speed 2 \
		--traffic diurnal --peak-qps 80 --trough-qps 4 --windows 12 \
		--delta ../artifacts/deltas/fleet-swap-smoke.json \
		--out target/replan/fleet-swap.json --check-equal

trace-smoke:
	rm -rf $(RUST_DIR)/target/trace-smoke
	mkdir -p $(RUST_DIR)/target/trace-smoke
	cd $(RUST_DIR) && cargo run --release -- search \
		--model qwen3-32b --gpu h100 --framework trtllm \
		--isl 4000 --osl 500 --ttft 2000 --speed 10 --prune \
		--trace-out target/trace-smoke/search-trace.json \
		--explain --explain-out target/trace-smoke/search-explain.json
	cd $(RUST_DIR) && cargo run --release -- plan \
		--model llama3.1-8b --fleet h100,a100 --framework trtllm \
		--isl 256 --osl 32 --ttft 5000 --speed 2 \
		--traffic diurnal --peak-qps 80 --trough-qps 4 --windows 12 \
		--trace-out target/trace-smoke/plan-trace.json \
		--explain --explain-out target/trace-smoke/plan-explain.json
	cd $(RUST_DIR) && cargo test --test artifacts trace_smoke -- --nocapture

measurements:
	$(PYTHON) python/measurements/synth.py

build:
	cd $(RUST_DIR) && cargo build --release

test:
	cd $(RUST_DIR) && cargo test -q

bench:
	cd $(RUST_DIR) && cargo bench --bench table1_search
	cd $(RUST_DIR) && cargo bench --bench sweep

bench-plan:
	cd $(RUST_DIR) && cargo bench --bench planner

bench-topo:
	cd $(RUST_DIR) && cargo bench --bench topology

bench-service:
	cd $(RUST_DIR) && cargo bench --bench service

bench-validate:
	cd $(RUST_DIR) && cargo bench --bench validate

bench-replan:
	cd $(RUST_DIR) && cargo bench --bench replan

bench-trace:
	cd $(RUST_DIR) && cargo bench --bench trace

bench-budget:
	rm -rf $(RUST_DIR)/target/bench-baseline
	mkdir -p $(RUST_DIR)/target/bench-baseline
	cp BENCH_plan.json BENCH_topology.json BENCH_replan.json BENCH_trace.json \
		$(RUST_DIR)/target/bench-baseline/
	cd $(RUST_DIR) && cargo bench --bench sweep
	cd $(RUST_DIR) && cargo bench --bench planner
	cd $(RUST_DIR) && cargo bench --bench topology
	cd $(RUST_DIR) && cargo bench --bench replan
	cd $(RUST_DIR) && cargo bench --bench trace
	cd $(RUST_DIR) && cargo test --test artifacts -q
	$(PYTHON) python/bench_budget.py \
		--baseline $(RUST_DIR)/target/bench-baseline --current . --tolerance 0.15

bench-all: bench bench-plan bench-topo bench-service bench-validate bench-replan bench-trace
	cd $(RUST_DIR) && cargo bench --bench interp_hot_path
	cd $(RUST_DIR) && cargo bench --bench calibration
	cd $(RUST_DIR) && cargo bench --bench simulator
	cd $(RUST_DIR) && cargo bench --bench experiments

# AOT Pallas -> HLO artifacts consumed by the (feature-gated) PJRT
# runtime. The Python toolchain (jax + the compile package) may be
# unavailable in CI or offline images; in that case this target is a
# no-op with a note, and every consumer (benches, examples, tests,
# --pjrt flags) skips the PJRT path automatically.
artifacts:
	@if $(PYTHON) -c "import jax" 2>/dev/null; then \
		cd python && $(PYTHON) -m compile.aot --out-dir ../$(RUST_DIR)/artifacts; \
	else \
		echo "make artifacts: jax not importable — skipping AOT lowering."; \
		echo "The native interpolation path is used instead; PJRT-gated"; \
		echo "tests/benches/examples detect the missing artifacts and skip."; \
	fi

fmt:
	cd $(RUST_DIR) && cargo fmt --check

clippy:
	cd $(RUST_DIR) && cargo clippy -- -D warnings

clean:
	cd $(RUST_DIR) && cargo clean
